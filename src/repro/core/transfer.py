"""Predicate transfer core: join graph, transfer graph, schedules, strategies.

Implements the paper's §3 exactly:

* the *join graph* is extracted from the query plan (vertex = base relation
  after local predicates, edge = equi-join);
* the *predicate transfer graph* orients every edge from the smaller
  (post-local-filter) relation to the larger one — a total order on
  vertices, hence a DAG, with no edge removed (works on cyclic graphs);
* the schedule is one **forward pass** (topological order; each vertex
  applies all incoming Bloom filters in one scan, then emits transformed
  outgoing filters) and one symmetric **backward pass**;
* outer/anti joins restrict the allowed transfer direction (§3.4);
* `Yannakakis` replaces Bloom filters with precise semi-joins over a BFS
  join tree (cycle edges dropped), `BloomJoin` does one-hop build→probe
  filtering inside each join, `NoPredTrans` does nothing — the paper's
  three baselines.

All per-row work (hashing, Bloom build/probe/transfer) runs through the
batched engine layer `repro.core.engine_bloom` — backend-pluggable over
the `repro.core.bloom` host/jnp ops and the `repro.kernels.bloom` Pallas
TPU kernels, all with identical filter semantics.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import bloom, provenance
from repro.core.bloom import MinMaxFilter

if TYPE_CHECKING:   # type-only: the cache is duck-typed at runtime
    from repro.core.artifact_cache import ArtifactCache
from repro.core.engine_bloom import BloomEngine, EngineKeys, get_engine
from repro.core.graph import (  # noqa: F401  (re-exported)
    Edge, EdgeDecision, NoPredTrans, Strategy, TransferStats, Vertex,
)
from repro.relational import ops

# strategies that take a `backend=` engine switch (numpy | jax | pallas)
BACKEND_AWARE = {"bloom-join", "pred-trans", "pred-trans-opt",
                 "pred-trans-adaptive"}


class BloomJoin(Strategy):
    """One-hop, one-direction Bloom filtering inside each join (paper §2.1)."""

    name = "bloom-join"
    uses_per_join_filter = True

    def __init__(self, bits_per_key: int = bloom.DEFAULT_BITS_PER_KEY,
                 k: int = bloom.DEFAULT_K, backend: str = "numpy",
                 interpret: Optional[bool] = None,
                 device_resident: Optional[bool] = None):
        self.bits_per_key = bits_per_key
        self.engine: BloomEngine = get_engine(
            backend, k=k, interpret=interpret,
            device_resident=device_resident)

    def prefilter(self, vertices, edges, ctx=None, hints=None):
        # no transfer phase, but record which engine the per-join
        # filters below will run on
        return TransferStats(strategy=self.name,
                             backend=self.engine.backend)

    def cache_signature(self):
        # prefilter is a no-op, so post-transfer slot state is the bare
        # compacted scan — shared with NoPredTrans (the per-join
        # filtering happens later, inside the join phase)
        return ("none",)

    def per_join_filter(self, build, probe, build_keys, probe_keys, stats):
        bk = self.engine.keys(ops.composite_key(build, build_keys))
        # NULL-tight: NULL build keys never match, so they stay out of
        # the filter (and its sizing)
        filt = self.engine.build_filter(
            bk, bits_per_key=self.bits_per_key,
            valid=ops.key_validity(build, build_keys))
        pk = self.engine.keys(ops.composite_key(probe, probe_keys))
        hit = self.engine.probe_filter(filt, pk)
        stats.filters_built += 1
        stats.filter_bytes += filt.nbytes()
        stats.rows_probed += len(pk)
        return hit


def _edge_label(src: Vertex, dst: Vertex, cols: Sequence[str]) -> str:
    return f"{src.alias}->{dst.alias}[{','.join(cols)}]"


def _transfer_order(vertices: Dict[int, Vertex],
                    live: Optional[Dict[int, int]] = None) -> List[int]:
    """Small -> large total order (paper §3.2 heuristic). Ties broken by
    leaf id; the orientation is therefore acyclic by construction."""
    if live is None:
        live = {lid: v.live for lid, v in vertices.items()}
    return [lid for lid in sorted(vertices,
                                  key=lambda lid: (live[lid], lid))]


class PredTrans(Strategy):
    """The paper's contribution. Forward + backward Bloom-filter passes over
    the small→large DAG; each vertex applies all incoming filters and emits
    transformed outgoing filters from a single scan, executed by the
    batched `repro.core.engine_bloom` runtime (`backend=` selects the
    numpy host mirror, the jit'd jnp ops, or the Pallas TPU kernels)."""

    name = "pred-trans"

    def __init__(self, bits_per_key: int = bloom.DEFAULT_BITS_PER_KEY,
                 k: int = bloom.DEFAULT_K, passes: int = 2,
                 prune: bool = False, lip_order: bool = True,
                 backend: str = "numpy",
                 interpret: Optional[bool] = None,
                 device_resident: Optional[bool] = None,
                 artifact_cache: Optional["ArtifactCache"] = None):
        self.bits_per_key = bits_per_key
        self.k = k
        self.passes = passes  # 2 = forward+backward (paper); more allowed
        # prune: skip filters built from complete, untouched base relations
        # (they cannot reject FK-valid rows). The paper names this
        # "transfer path pruning" but leaves it out of its prototype, so
        # the faithful default is off; "pred-trans-opt" turns it on.
        self.prune = prune
        # lip_order: apply incoming filters most-selective-first (LIP-style
        # ordering, explicitly sanctioned in paper §3.2).
        self.lip_order = lip_order
        self.engine: BloomEngine = get_engine(
            backend, k=k, interpret=interpret,
            device_resident=device_resident)
        # cross-query transfer-artifact cache (DESIGN.md §12): filter
        # builds whose provenance signature matches an entry are reused
        # instead of rebuilt; None = per-query behavior, no sharing
        self.artifact_cache = artifact_cache

    def cache_signature(self):
        return ("pred-trans", self.bits_per_key, self.k, self.passes,
                self.prune, self.lip_order)

    # -- cross-query filter reuse (DESIGN.md §12) ----------------------
    def _cached_filter(self, fsig: Optional[bytes]):
        """(words, minmax) from the shared cache, or None."""
        if self.artifact_cache is None or fsig is None:
            return None
        return self.artifact_cache.get(("bloom", fsig))

    def _store_filter(self, fsig: Optional[bytes], words, mm,
                      v: Vertex, cost_ns: Optional[float] = None
                      ) -> None:
        if self.artifact_cache is None or fsig is None:
            return
        from repro.core import device_plane
        # host-resident: shareable across engine backends
        # (bit-identical); a device-resident build syncs here, counted
        host = device_plane.to_host(words)
        self.artifact_cache.put(
            ("bloom", fsig), (host, mm), nbytes=host.nbytes + 32,
            versions=v.dep_versions, cost_ns=cost_ns)

    def prefilter(self, vertices, edges, ctx=None, hints=None):
        self._ctx = ctx
        # history-corrected selectivity estimates, keyed
        # (edge_label, pass_idx) — per-query scratch, supplied by the
        # executor from `plancache.SelHistory` on repeat fingerprints
        self._hints = hints or {}
        stats = TransferStats(strategy=self.name,
                              backend=self.engine.backend)
        # initial live counts, shared with the adaptive scheduler's
        # live cache (mask.sum() is O(rows) — never re-sum a mask
        # nothing touched)
        self._live0 = before = {lid: v.live
                                for lid, v in vertices.items()}
        t0 = time.perf_counter()
        order = _transfer_order(vertices, before)
        rank = {lid: i for i, lid in enumerate(order)}
        self._hk_cache: Dict[Tuple[int, Tuple[str, ...]],
                             EngineKeys] = {}
        # per-vertex edge adjacency, computed once per prefilter (the
        # passes below are O(V + E) per pass, not O(V·E))
        adj: Dict[int, List[Tuple[int, Edge]]] = {lid: []
                                                 for lid in vertices}
        for ei, e in enumerate(edges):
            if e.u in adj:
                adj[e.u].append((ei, e))
            if e.v in adj and e.v != e.u:
                adj[e.v].append((ei, e))

        self._run_passes(order, rank, vertices, adj, stats)

        # NaN-free actual-selectivity contract (graph.EdgeDecision): an
        # edge whose probe never ran — skipped, pruned, batched away by
        # a min-max cut or an earlier empty survivor set — measured
        # zero removed rows over zero probed rows
        for d in stats.edges:
            if math.isnan(d.act_sel):
                d.act_sel = 0.0

        stats.seconds = time.perf_counter() - t0
        stats.record_vertices(vertices, before,
                              after=getattr(self, "_lives", None))
        return stats

    def _run_passes(self, order, rank, vertices, adj, stats):
        for p in range(self.passes):
            if self._ctx is not None:
                self._ctx.check("transfer")
            forward = (p % 2 == 0)
            seq = order if forward else order[::-1]
            self._one_pass(seq, rank, forward, vertices, adj, stats, p)
            stats.passes_run += 1

    def _hashed(self, v: Vertex, cols: Sequence[str]) -> EngineKeys:
        """Hash a vertex's key column once and reuse across all edges and
        passes (the paper's one-scan transformation, vectorized). The
        raw composite key is stashed on the vertex so the join phase
        reuses it too (`repro.core.engine_join`)."""
        key = (v.leaf_id, tuple(cols))
        hk = self._hk_cache.get(key)
        if hk is None:
            hk = self.engine.keys(v.key(cols))
            self._hk_cache[key] = hk
        return hk

    def _one_pass(self, seq, rank, forward, vertices, adj, stats,
                  pass_idx):
        """Process vertices in `seq` order; a filter flows along edge
        (a,b) iff rank order matches the pass direction and the edge
        allows that direction."""
        # pending[edge_idx] = (filter, source selectivity estimate,
        #                      filter provenance sig, source versions)
        pending: Dict[int, Tuple[bloom.BloomFilter, float,
                                 Optional[bytes], frozenset]] = {}

        def flows(src: int, dst: int, e: Edge) -> bool:
            ok_dir = (rank[src] < rank[dst]) == forward and src != dst
            return ok_dir and e.allows(src, dst)

        for lid in seq:
            if self._ctx is not None:
                self._ctx.check()       # per-vertex cancellation point
            v = vertices[lid]
            scan = self.engine.begin(v.mask)
            # 1. apply all incoming filters — one fused multi-filter
            #    probe over a single shrinking survivor set (rows leave
            #    the working set as soon as one filter misses)
            incoming = []
            for ei, e in adj[lid]:
                src = e.other(lid)
                if flows(src, lid, e) and ei in pending:
                    incoming.append((pending[ei][1], ei, e))
            if self.lip_order:          # most selective first (LIP-style)
                incoming.sort(key=lambda t: t[0])
            if incoming:
                before = scan.live
                stats.rows_probed += scan.probe(
                    [(pending[ei][0].words,
                      self._hashed(v, e.endpoint_cols(lid)))
                     for _, ei, e in incoming])
                v.mask = scan.mask
                # a probe that removed nothing left the survivor row
                # set — and so its provenance signature — unchanged
                if scan.live != before:
                    v.apply_filters_sig(
                        [(pending[ei][2],
                          v.canon_cols(e.endpoint_cols(lid)))
                         for _, ei, e in incoming],
                        [pending[ei][3] for _, ei, e in incoming])
            # 2. build transformed outgoing filters from the same
            #    survivor set — probe→build is one scan, never a rescan
            out_edges = [(ei, e) for ei, e in adj[lid]
                         if flows(lid, e.other(lid), e)]
            if not out_edges:
                continue
            live = scan.live
            if self.prune and not v.informative:
                # transfer-path pruning (§3.2) — skipped edges still
                # report a decision (0 probed rows), never vanish.
                # Destination counts come from the pre-transfer cache:
                # stats bookkeeping must not re-popcount masks inside
                # the timed loop.
                for ei, e in out_edges:
                    dv = vertices[e.other(lid)]
                    stats.edges.append(EdgeDecision(
                        _edge_label(v, dv, e.endpoint_cols(lid)),
                        pass_idx, "pruned", build_rows=live,
                        probe_rows=self._live0.get(dv.leaf_id, 0),
                        src=v.alias, dst=dv.alias))
                continue
            nblocks = bloom.blocks_for(max(live, 1), self.bits_per_key)
            sel = live / max(v.base_rows if v.base_rows > 0
                             else len(v.table), 1)
            built: Dict[int, tuple] = {}        # same cols => same filter
            for ei, e in out_edges:
                cols = e.endpoint_cols(lid)
                hk = self._hashed(v, cols)
                hit = built.get(id(hk))
                if hit is None:
                    fsig = provenance.filter_sig(
                        v.state_sig, v.canon_cols(cols), nblocks,
                        self.k)
                    ent = self._cached_filter(fsig)
                    if ent is not None:
                        words = ent[0]
                        stats.filters_reused += 1
                    else:
                        # NULL-tight: invalid-key rows never match, so
                        # they never earn filter bits (the vertex mask —
                        # and the filter sizing by live rows — stay
                        # untouched)
                        t0b = time.perf_counter_ns()
                        words = scan.build(hk, nblocks,
                                           valid=v.key_valid(cols))
                        self._store_filter(
                            fsig, words, None, v,
                            cost_ns=time.perf_counter_ns() - t0b)
                    built[id(hk)] = hit = (words, fsig)
                words, fsig = hit
                filt = bloom.BloomFilter(words, self.k)
                pending[ei] = (filt, sel, fsig, v.dep_versions)
                stats.filters_built += 1
                stats.filter_bytes += filt.nbytes()


# --------------------------------------------------------------------------
# adaptive cost-gated scheduling (DESIGN.md §11)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransferCosts:
    """Per-row cost coefficients (ns) for the adaptive scheduler's
    skip/apply decision (DESIGN.md §11).

    The *cost* side is linear: hash+probe per probe-side row and
    hash+build per build-side row, measured per backend by
    `benchmarks/kernel_bench.calibrate` (recorded in BENCH_tpch.json
    under "transfer_cost_calibration").

    The *benefit* side is two-regime: the per-row join work a removed
    row saves depends on scale. Below `large_n` rows a join's build
    side is cache-resident and its probe+assembly costs about as much
    as the Bloom probe itself (`join_small`); above it, sorts and
    searches go memory-bound and each surviving row is several times
    more expensive (`join_large`). The boundary is the same
    measurement family as the sorted-vs-radix crossover
    (`kernel_bench.join_crossover` / `engine_join.RADIX_MIN`).
    Absolute accuracy is not required — only the cost/benefit *ratio*
    gates an edge, and the `--check` bench gate (paired
    adaptive/pred-trans ratios, per query) enforces the end-to-end
    consequences."""

    probe: float        # Bloom probe (incl. hash) per probe-side row
    build: float        # filter build (incl. hash) per build-side row
    join_small: float   # downstream join ns/row, cache-resident case
    join_large: float   # downstream join ns/row, memory-bound case
    # fixed per-applied-edge cost (ns): hash/probe/build dispatch and
    # estimation overhead is size-independent at the bottom (a 25-row
    # probe costs the same as a 1000-row one — kernel_bench measures
    # it as the probe time at tiny n). Edges whose whole benefit is
    # below this are pure overhead no matter how selective.
    fixed: float = 300_000.0
    # the large regime needs the vertex itself past this row count …
    # (same measurement family as the sorted-vs-radix crossover,
    # engine_join.RADIX_MIN — the join goes memory-bound about one
    # power of two before radix partitioning starts paying)
    large_n: int = 1 << 17
    # … and its joins to actually be expensive: either some partner
    # brings enough rows to pay repeated searches into the
    # DRAM-resident structure, or the vertex's own join key is
    # unsorted (its build-side argsort is O(n log n) random access;
    # a presorted key — TPC-H's o_orderkey — sorts as one run)
    partner_min: int = 1 << 12
    # transfer reductions propagate: a vertex shrunk here emits
    # smaller, more selective filters to its downstream neighbors in
    # the same pass. gamma discounts that transitive benefit per hop.
    gamma: float = 0.5


#: operating point seeded from `kernel_bench.calibrate` and tuned
#: end-to-end against the BENCH_tpch.json acceptance sweep (DESIGN.md
#: §11 — the microbench measures worst-case shapes, e.g. 100%-match
#: joins and cold hash state, so the in-query coefficients below sit
#: under the raw `transfer_cost_calibration` numbers; the *ratios*
#: are what gate an edge). The pallas backend runs in interpret mode
#: off-TPU, so its per-row coefficients are larger and the scheduler
#: skips more aggressively there.
DEFAULT_COSTS: Dict[str, TransferCosts] = {
    "numpy": TransferCosts(probe=45.0, build=45.0,
                           join_small=40.0, join_large=110.0),
    "jax": TransferCosts(probe=30.0, build=60.0,
                         join_small=40.0, join_large=110.0,
                         fixed=500_000.0),
    "pallas": TransferCosts(probe=160.0, build=340.0,
                            join_small=40.0, join_large=110.0,
                            fixed=500_000.0),
}


@dataclasses.dataclass
class _Emitted:
    """One emitted (or cached) filter in flight along an edge."""

    words: np.ndarray
    mm: Optional[MinMaxFilter]
    sel_est: float
    decision: EdgeDecision
    sig: Optional[bytes] = None       # filter provenance signature
    deps: frozenset = frozenset()     # source Table.version set


class AdaptivePredTrans(PredTrans):
    """Cost-gated predicate transfer (`pred-trans-adaptive`).

    Plain PredTrans pays for every edge in every pass; on queries where
    a transfer's build+probe cost exceeds the work its removed rows
    would have caused downstream, pre-filtering is a net loss (9 of 20
    TPC-H queries in BENCH_tpch.json before this scheduler). Per edge
    and per pass this strategy:

    * models the transfer cost ``c_build·|build live| +
      c_probe·|probe live|`` against the benefit ``sel_est · |probe
      live| · c_downstream`` and skips the edge when it cannot pay —
      `sel_est` is the estimated removed-row fraction, derived from the
      build side's live distinct-key count (KMV over the hash state the
      build needs anyway, `bloom.kmv_distinct`) over the edge's key
      domain (the smaller endpoint's base cardinality);
    * publishes a min-max range filter next to each Bloom filter
      (`bloom.MinMaxFilter`, built from the same live-key scan):
      provably disjoint ranges short-circuit the edge without a single
      probe (and an emptied vertex's empty range cascades for free),
      a contained probe range skips the range test, anything else
      applies the O(1)-per-row comparison *before* the Bloom probe;
    * early-exits the pass loop when a pass's total removed-row count
      falls below `early_exit_frac` of the live rows entering it, and
      caches filter builds across passes so a vertex whose survivor
      set did not change never rebuilds (or re-ranges) its filter;
    * records every decision as an `EdgeDecision` (estimated vs actual
      selectivity, modeled cost/benefit, 0 probed rows for skips) in
      `TransferStats.edges` — `benchmarks/run.py` persists them.

    Skipping any subset of edges only *grows* survivor sets; the join
    phase recomputes exact matches, so query results are bit-identical
    to the always-apply oracle (tests/test_transfer_adaptive.py sweeps
    `mode="force_skip" | "force_apply" | "auto"` across all engines).
    The distributed runtime reuses the same decisions — the transfer
    phase runs once on the host graph regardless of join engine — so a
    skipped edge also skips its filter broadcast
    (benchmarks/distributed_transfer.py accounts the saved bytes)."""

    name = "pred-trans-adaptive"

    MODES = ("auto", "force_apply", "force_skip")

    def __init__(self, bits_per_key: int = bloom.DEFAULT_BITS_PER_KEY,
                 k: int = bloom.DEFAULT_K, passes: int = 2,
                 lip_order: bool = True, backend: str = "numpy",
                 interpret: Optional[bool] = None,
                 device_resident: Optional[bool] = None,
                 mode: str = "auto",
                 costs: Optional[TransferCosts] = None,
                 minmax: bool = True,
                 early_exit_frac: float = 0.001,
                 artifact_cache: Optional["ArtifactCache"] = None):
        super().__init__(bits_per_key=bits_per_key, k=k, passes=passes,
                         prune=False, lip_order=lip_order,
                         backend=backend, interpret=interpret,
                         device_resident=device_resident,
                         artifact_cache=artifact_cache)
        if mode not in self.MODES:
            raise ValueError(f"mode must be one of {self.MODES}, "
                             f"got {mode!r}")
        self.mode = mode
        self.costs = costs or DEFAULT_COSTS[self.engine.backend]
        # min-max only makes sense when edges actually run (force_apply
        # must reproduce the always-apply oracle's survivor sets)
        self.minmax = minmax and mode == "auto"
        self.early_exit_frac = early_exit_frac

    def cache_signature(self):
        # the cost model gates which edges apply, so every coefficient
        # shapes the survivor masks — the per-backend DEFAULT_COSTS
        # differ, which is why `costs` is in and `backend` stays out
        return (("pred-trans-adaptive", self.bits_per_key, self.k,
                 self.passes, self.lip_order, self.mode, self.minmax,
                 self.early_exit_frac)
                + dataclasses.astuple(self.costs))

    # -- pass loop with early exit ------------------------------------
    def _run_passes(self, order, rank, vertices, adj, stats):
        # key-domain bounds per (vertex, endpoint cols): the smallest
        # base cardinality among the non-derived endpoints of every
        # edge sharing those columns. A dimension PK bounds the FK
        # domain of *every* relation joining on it — e.g. a derived
        # subquery carrying all 20k partkeys estimates sel 0 against
        # lineitem because `part` (base 20k) bounds l_partkey's
        # domain. Derived sources are excluded: their keys are a
        # filtered subset of some larger domain, so their row count
        # bounds nothing.
        self._dom: Dict[Tuple, int] = {}
        for lid, pairs in adj.items():
            v = vertices[lid]
            for ei, e in pairs:
                o = vertices.get(e.other(lid))
                if o is None:
                    continue
                key = (lid, tuple(e.endpoint_cols(lid)))
                cur = self._dom.get(key)
                if cur is None:
                    cur = v.base_rows if (not v.derived
                                          and v.base_rows > 0) \
                        else len(v.table)
                if not o.derived and o.base_rows > 0:
                    cur = min(cur, o.base_rows)
                self._dom[key] = cur
        # per-prefilter caches: filters/ranges by (leaf, cols) with the
        # live count AND provenance signature they were built at;
        # distinct estimates by (leaf, cols, live); conservative
        # probe-side ranges by (leaf, cols)
        self._fcache: Dict[Tuple, Tuple[np.ndarray,
                                        Optional[MinMaxFilter],
                                        int, Optional[bytes],
                                        int]] = {}
        self._dcache: Dict[Tuple, int] = {}
        self._rcache: Dict[Tuple, Optional[Tuple[int, int]]] = {}
        self._rcache2: Dict[int, float] = {}    # per-vertex join rate
        # live-count cache: mask.sum() is O(rows) and the scheduler
        # reads counts per edge — seeded from the prefilter's initial
        # counts, refreshed from the scan only when a vertex's mask
        # actually changed
        self._lives: Dict[int, int] = dict(self._live0)
        before = sum(self._lives.values())
        for p in range(self.passes):
            if self._ctx is not None:
                self._ctx.check("transfer")
            forward = (p % 2 == 0)
            seq = order if forward else order[::-1]
            self._one_pass(seq, rank, forward, vertices, adj, stats, p)
            stats.passes_run += 1
            after = sum(self._lives[lid] for lid in vertices)
            removed, entering = before - after, before
            before = after
            if self.mode == "force_apply":
                continue            # the always-apply oracle runs all
            if removed < max(1, int(self.early_exit_frac * entering)):
                break               # pass early-exit (DESIGN §11)

    # -- helpers -------------------------------------------------------
    def _fcache_get(self, lid: int, cols: Tuple[str, ...], live: int,
                    sig: Optional[bytes]):
        """Per-query filter-cache lookup, validated by the provenance
        signature of the vertex's *current* survivor state. The PR-5
        key validated by live count alone and could collide across
        predicate states that keep equal row counts over different
        rows; the signature cannot. The live-count check survives only
        as the fallback for signature-less vertices (constructed
        outside the executor), where it is sound: masks shrink
        monotonically within one prefilter, so an unchanged count means
        an unchanged mask."""
        cached = self._fcache.get((lid, cols))
        if cached is None:
            return None
        _, _, clive, csig, _ = cached
        if sig is None and csig is None:
            return cached if clive == live else None
        return cached if csig == sig else None

    def _rangeable(self, v: Vertex, cols: Tuple[str, ...]) -> bool:
        """Ranges are only meaningful for order-preserving composite
        encodings: single non-dictionary columns, or the packed
        two-column path. The hash-combine fallback scrambles order."""
        if any(v.table[c].dictionary is not None for c in cols):
            return False
        if len(cols) == 1:
            return True
        if len(cols) == 2:
            return ops.stable_key_encoding(v.table, cols)
        return False

    def _cons_range(self, v: Vertex, cols: Tuple[str, ...]
                    ) -> Optional[Tuple[int, int]]:
        """Conservative (possibly inherited, never rescanned) bounds on
        the vertex's key values — the probe side of the disjoint /
        contained tests. Wider-than-live bounds only make the checks
        more conservative, never wrong."""
        key = (v.leaf_id, cols)
        if key not in self._rcache:
            if not self._rangeable(v, cols):
                self._rcache[key] = None
            elif len(cols) == 1:
                self._rcache[key] = v.table[cols[0]].value_range()
            else:
                (alo, ahi) = v.table[cols[0]].value_range()
                (blo, bhi) = v.table[cols[1]].value_range()
                self._rcache[key] = ((alo << 32) | blo,
                                     (ahi << 32) | bhi)
        return self._rcache[key]

    def _sel_est(self, v: Vertex, scan, cols: Tuple[str, ...],
                 dv: Vertex, dcols: Tuple[str, ...]) -> float:
        """Estimated fraction of `dv`'s live rows an edge filter from
        `v` would remove: 1 - d_live / domain, where d_live is the KMV
        distinct estimate over the build side's live key hashes (reused
        by the build itself) and domain is the edge's key-domain bound
        (`self._dom`) — the smallest non-derived base cardinality among
        the endpoints of every edge sharing the destination's key
        columns (a derived build side's keys are a filtered subset of
        some larger domain, so its own row count bounds nothing)."""
        live = scan.live
        if live == 0:
            return 1.0
        ck = (v.leaf_id, cols, live)
        d = self._dcache.get(ck)
        if d is None:
            hk = self._hashed(v, cols)
            d = bloom.kmv_distinct(scan.live_hashes(hk))
            self._dcache[ck] = d
        dom = self._dom.get((dv.leaf_id, dcols),
                            dv.base_rows if dv.base_rows > 0
                            else len(dv.table))
        if not v.derived and v.base_rows > 0:
            dom = min(dom, v.base_rows)
        return 1.0 - min(1.0, d / max(dom, 1))

    def _live_range(self, v: Vertex, scan, cols: Tuple[str, ...]
                    ) -> Optional[MinMaxFilter]:
        """Exact [lo, hi] of the live, valid keys — the emitted edge's
        min-max filter, computed from the same survivor scan the Bloom
        build reads."""
        if not self._rangeable(v, cols):
            return None
        rng = scan.key_range(v.key(cols), ek=self._hashed(v, cols),
                             valid=v.key_valid(cols))
        if rng is None:
            # no live, valid key: the empty (inverted) range — disjoint
            # with everything, so an emptied vertex cascades for free
            return MinMaxFilter(0, -1)
        return MinMaxFilter(*rng)

    # -- the scheduled pass --------------------------------------------
    def _join_rate(self, lid: int, vertices, adj) -> float:
        """Modeled ns saved downstream per removed row of vertex `lid`
        (DESIGN §11): the per-join rate — memory-bound `join_large`
        when the vertex is big and its joins are actually expensive
        (some partner past the cache-resident build size, or its own
        join key unsorted so the build-side argsort pays full price),
        else cache-resident `join_small` — times the number of joins a
        surviving row flows through (`Vertex.join_depth`)."""
        rate = self._rcache2.get(lid)
        if rate is not None:
            return rate
        costs = self.costs
        v = vertices[lid]
        live0 = self._live0[lid]
        base = costs.join_small
        if live0 >= costs.large_n:
            maxp = max((self._live0[e.other(lid)]
                        for ei, e in adj[lid]
                        if e.other(lid) in self._live0), default=0)
            if maxp >= costs.partner_min:
                base = costs.join_large
            else:
                for ei, e in adj[lid]:
                    k = v.key(e.endpoint_cols(lid))
                    if len(k) and not bool(np.all(k[1:] >= k[:-1])):
                        base = costs.join_large
                        break
        rate = base * v.join_depth
        self._rcache2[lid] = rate
        return rate

    def _reach(self, seq, vertices, adj, flows) -> Dict[int, float]:
        """Damped downstream row-mass per vertex for this pass:
        R(x) = live(x)·join_rate(x) + gamma·Σ R(y) over the vertices
        x's filters flow to. The benefit of removing a fraction of x's
        rows is that fraction of R(x): the rows' own downstream join
        work plus the (per-hop discounted) shrinkage of the filters x
        emits later in the pass. A downstream edge only contributes if
        it is itself gate-1 feasible (probing y must cost less than
        y's reach) — a chain that dead-ends in an edge the scheduler
        will skip propagates nothing. One O(V+E) walk in reverse pass
        order (downstream vertices are later in `seq`, so their R is
        already final when x is visited)."""
        costs = self.costs
        lives = self._lives
        R: Dict[int, float] = {}
        for lid in reversed(seq):
            r = lives[lid] * self._join_rate(lid, vertices, adj)
            for ei, e in adj[lid]:
                dst = e.other(lid)
                if flows(lid, dst, e) \
                        and costs.probe * lives[dst] < R[dst]:
                    r += costs.gamma * R[dst]
            R[lid] = r
        return R

    def _one_pass(self, seq, rank, forward, vertices, adj, stats,
                  pass_idx):
        pending: Dict[int, _Emitted] = {}
        costs = self.costs

        def flows(src: int, dst: int, e: Edge) -> bool:
            ok_dir = (rank[src] < rank[dst]) == forward and src != dst
            return ok_dir and e.allows(src, dst)

        lives = self._lives

        def live_of(dv: Vertex) -> int:
            n = lives.get(dv.leaf_id)
            if n is None:
                lives[dv.leaf_id] = n = dv.live
            return n

        reach = self._reach(seq, vertices, adj, flows) \
            if self.mode == "auto" else {}
        # expected surviving fraction per destination this pass: edges
        # into one vertex share a fused probe, so a later filter only
        # probes — and only removes — what the earlier ones left.
        # Costs and benefits both shrink by the accumulated factor.
        surv: Dict[int, float] = {}

        for lid in seq:
            if self._ctx is not None:
                self._ctx.check()       # per-vertex cancellation point
            v = vertices[lid]
            scan = self.engine.begin(v.mask)

            # 1. incoming filters: min-max first (disjoint ranges cut
            #    the edge — and possibly the vertex — without a probe),
            #    then one fused Bloom probe in LIP order
            incoming = [(pending[ei], ei, e) for ei, e in adj[lid]
                        if flows(e.other(lid), lid, e) and ei in pending]
            if self.lip_order:      # most selective (est.) first
                incoming.sort(key=lambda t: -t[0].sel_est)
            cut = False
            for pf, ei, e in incoming:
                cols = tuple(e.endpoint_cols(lid))
                if pf.mm is None or not self.minmax:
                    continue
                cons = self._cons_range(v, cols)
                if cons is None:
                    continue
                if pf.mm.disjoint(*cons):
                    # no live key can pass: the edge removes everything
                    # without one hash — incl. the empty-build cascade
                    # (an emptied vertex emits an empty range)
                    scan.clear()
                    if pf.sig is None:
                        v.state_sig = None
                    else:
                        v.chain_event(("cut", pf.sig), pf.deps)
                    pf.decision.action = "minmax-cut"
                    pf.decision.act_sel = 1.0
                    cut = True
                    break
                if not pf.mm.contains(*cons):
                    # the O(1)-per-row test pays only when the overlap
                    # suggests it removes rows: under uniform keys the
                    # expected removal is 1 - overlap/width
                    lo = max(cons[0], pf.mm.lo)
                    hi = min(cons[1], pf.mm.hi)
                    width = max(cons[1] - cons[0] + 1, 1)
                    if (hi - lo + 1) / width < 0.98:
                        n0 = scan.live
                        stats.rows_range_tested += scan.probe_range(
                            v.key(cols), pf.mm.lo, pf.mm.hi,
                            ek=self._hashed(v, cols))
                        # the signature names the survivor *row set*:
                        # a cut that removed nothing left it unchanged
                        if scan.live != n0:
                            v.chain_event(("range", v.canon_cols(cols),
                                           int(pf.mm.lo),
                                           int(pf.mm.hi)),
                                          pf.deps)
            if cut:
                v.mask = scan.mask
            elif incoming:
                enter = before = scan.live
                stats.rows_probed += scan.probe(
                    [(pf.words, self._hashed(v, e.endpoint_cols(lid)))
                     for pf, ei, e in incoming])
                for (pf, ei, e), after in zip(incoming,
                                              scan.live_after):
                    pf.decision.rows_probed += enter
                    if enter > 0:
                        pf.decision.act_sel = 1.0 - after / enter
                    enter = after
                v.mask = scan.mask
                # `enter` is now the post-probe live count: a fused
                # probe that removed nothing left the row set — and so
                # its signature — unchanged (cross-pass filter reuse)
                if enter != before:
                    v.apply_filters_sig(
                        [(pf.sig, v.canon_cols(e.endpoint_cols(lid)))
                         for pf, ei, e in incoming],
                        [pf.deps for pf, ei, e in incoming])

            if cut or incoming:
                lives[lid] = scan.live

            # 2. outgoing filters, cost-gated per edge
            out_edges = [(ei, e) for ei, e in adj[lid]
                         if flows(lid, e.other(lid), e)]
            if not out_edges:
                continue
            live = lives[lid]
            for ei, e in out_edges:
                dv = vertices[e.other(lid)]
                cols = tuple(e.endpoint_cols(lid))
                dec = EdgeDecision(_edge_label(v, dv, cols), pass_idx,
                                   "applied", build_rows=live,
                                   probe_rows=live_of(dv),
                                   src=v.alias, dst=dv.alias)
                stats.edges.append(dec)
                if self.mode == "force_skip":
                    dec.action = "skipped-forced"
                    continue
                cached = self._fcache_get(lid, cols, live, v.state_sig)
                c_build = 0.0 if cached is not None \
                    else costs.build * live
                dlive = dec.probe_rows
                if self.mode == "auto":
                    # Vertex.informative with the already-known live
                    # count (the property would re-popcount the mask)
                    informative = (v.derived or v.base_rows < 0
                                   or len(v.table) < v.base_rows
                                   or live < len(v.table))
                    if not informative and live > 0:
                        # complete untouched base relation: its filter
                        # cannot reject FK-valid rows (paper §3.2)
                        dec.action = "pruned"
                        dec.cost_ns = c_build + costs.probe * dlive
                        continue
                    frac = surv.get(dv.leaf_id, 1.0)
                    dec.cost_ns = cost = \
                        costs.fixed + c_build + \
                        costs.probe * dlive * frac
                    # gate 1: even removing every remaining probe row
                    # (sel = 1) can't pay — kills big-build and
                    # small-reach edges before any estimation work
                    cap = frac * reach[dv.leaf_id]
                    if cost >= cap:
                        dec.action = "skipped"
                        dec.est_sel = float("nan")
                        dec.benefit_ns = cap
                        continue
                    dec.est_sel = sel = self._sel_est(
                        v, scan, cols, dv,
                        tuple(e.endpoint_cols(e.other(lid))))
                    # second-query-onward correction: a measured actual
                    # for this (edge, pass) from an earlier run of the
                    # same plan fingerprint overrides the KMV estimate.
                    # Transfer filters have no false negatives, so a
                    # different gate outcome changes survivor sets but
                    # never query results.
                    hint = self._hints.get((dec.edge, pass_idx))
                    if hint is not None:
                        dec.est_sel = sel = min(max(hint, 0.0), 1.0)
                        stats.hints_used += 1
                    dec.benefit_ns = benefit = sel * cap
                    if benefit <= cost:
                        dec.action = "skipped"
                        continue
                    surv[dv.leaf_id] = frac * (1.0 - sel)
                else:
                    dec.cost_ns = c_build + costs.probe * dlive
                nblocks = bloom.blocks_for(max(live, 1),
                                           self.bits_per_key)
                fsig = provenance.filter_sig(
                    v.state_sig, v.canon_cols(cols), nblocks, self.k,
                    self.minmax)
                if cached is not None:
                    words, mm, _, _, nbytes = cached
                else:
                    ent = self._cached_filter(fsig)
                    if ent is not None:
                        words, mm = ent
                        nbytes = bloom.BloomFilter(words,
                                                   self.k).nbytes()
                        stats.filters_reused += 1
                    else:
                        t0b = time.perf_counter_ns()
                        hk = self._hashed(v, cols)
                        words = scan.build(hk, nblocks,
                                           valid=v.key_valid(cols))
                        mm = self._live_range(v, scan, cols) \
                            if self.minmax else None
                        build_ns = time.perf_counter_ns() - t0b
                        nbytes = bloom.BloomFilter(words,
                                                   self.k).nbytes()
                        stats.filters_built += 1
                        stats.filter_bytes += nbytes
                        dec.filter_bytes = nbytes
                        self._store_filter(fsig, words, mm, v,
                                           cost_ns=build_ns)
                    self._fcache[(lid, cols)] = (words, mm, live,
                                                 v.state_sig, nbytes)
                pending[ei] = _Emitted(words, mm, dec.est_sel, dec,
                                       fsig, v.dep_versions)


class Yannakakis(Strategy):
    """Semi-join reduction baseline (paper §2.2 / §4.1 extensions):
    BFS join tree from `root_seed`-chosen root (cycle edges dropped),
    bottom-up then top-down precise semi-join passes."""

    name = "yannakakis"

    def __init__(self, root_seed: int = 0):
        self.root_seed = root_seed

    def cache_signature(self):
        # the BFS tree (and so the final masks) depends only on the
        # seed-chosen root; semi-joins are exact, no filter params
        return ("yannakakis", self.root_seed)

    def prefilter(self, vertices, edges, ctx=None, hints=None):
        stats = TransferStats(strategy=self.name)
        before = {lid: v.live for lid, v in vertices.items()}
        t0 = time.perf_counter()

        ids = sorted(vertices.keys())
        if not ids:
            return stats
        rng = np.random.default_rng(self.root_seed)
        root = ids[int(rng.integers(0, len(ids)))]

        # BFS tree; keep first edge reaching each vertex, drop cycle edges
        adj: Dict[int, List[Tuple[int, Edge]]] = {i: [] for i in ids}
        for e in edges:
            adj[e.u].append((e.v, e))
            adj[e.v].append((e.u, e))
        parent: Dict[int, Optional[Tuple[int, Edge]]] = {root: None}
        bfs_order = [root]
        frontier = [root]
        while frontier:
            nxt = []
            for a in frontier:
                for b, e in adj[a]:
                    if b not in parent:
                        parent[b] = (a, e)
                        bfs_order.append(b)
                        nxt.append(b)
            frontier = nxt
        # disconnected leaves (cartesian subplans) just skip transfer
        reachable = [i for i in bfs_order if i in vertices]

        def semi(dst: int, src: int, e: Edge):
            """dst.mask &= dst ⋉ src (precise)."""
            if ctx is not None:
                ctx.check("transfer")   # per-semi-join cancellation
            if not e.allows(src, dst):
                return
            vd, vs = vertices[dst], vertices[src]
            dkeys = vd.key(e.endpoint_cols(dst))
            # NULL-tight: a NULL build key's representative bytes must
            # not keep spurious dst rows alive
            svalid = vs.key_valid(e.endpoint_cols(src))
            smask = vs.mask if svalid is None else vs.mask & svalid
            skeys = vs.key(e.endpoint_cols(src))[smask]
            hit = ops.semi_join_mask(dkeys, skeys)
            vd.mask &= hit
            # semi-join mask mutations are outside the transfer event
            # protocol — poison the provenance chain rather than let a
            # stale signature certify a filter from the wrong rows
            vd.state_sig = None
            stats.rows_semijoin_build += len(skeys)
            stats.rows_semijoin_probe += len(dkeys)

        # forward: bottom-up (children filter parents)
        for b in reversed(reachable):
            pa = parent.get(b)
            if pa is not None:
                a, e = pa
                semi(a, b, e)
        # backward: top-down (parents filter children)
        for b in reachable:
            pa = parent.get(b)
            if pa is not None:
                a, e = pa
                semi(b, a, e)

        stats.seconds = time.perf_counter() - t0
        stats.record_vertices(vertices, before)
        return stats


def _pred_trans_opt(**kw):
    kw.setdefault("prune", True)
    return PredTrans(**kw)


STRATEGIES = {
    "no-pred-trans": NoPredTrans,
    "bloom-join": BloomJoin,
    "yannakakis": Yannakakis,
    "pred-trans": PredTrans,          # paper-faithful (no pruning)
    "pred-trans-opt": _pred_trans_opt,  # + transfer-path pruning
    "pred-trans-adaptive": AdaptivePredTrans,  # + cost-gated scheduling
}


def make_strategy(name: str, **kw) -> Strategy:
    """`backend="numpy"|"jax"|"pallas"` selects the bloom engine for the
    strategies in BACKEND_AWARE; other strategies reject it (they do no
    Bloom work)."""
    if "backend" in kw and name not in BACKEND_AWARE:
        raise ValueError(f"strategy {name!r} takes no bloom backend")
    return STRATEGIES[name](**kw)
