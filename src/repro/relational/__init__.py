"""Columnar relational substrate.

A minimal-but-real in-memory columnar engine: `Table` holds named columns
(numpy arrays on host; bulk math is dispatched to JAX/Pallas kernels),
`expr` provides a vectorized predicate/projection AST, `ops` the physical
operators (hash/sort-merge equi-join, semi/anti join, group-agg, sort,
top-k), and `plan`/`executor` the logical plan IR and the strategy-aware
executor used by the predicate-transfer core.

Strings are dictionary-encoded at ingest; all engine math is on integer /
float codes (standard columnar practice, and what makes the whole engine
JAX-compatible).
"""

from repro.relational.table import Table, Column
from repro.relational.expr import (
    col, lit, isin, between, like, Expr, ExprValue, is_null, is_not_null,
    coalesce,
)
from repro.relational import ops
from repro.relational.plan import (
    Scan, Join, GroupBy, Project, Sort, Limit, SubqueryScan, PlanNode,
)
from repro.relational.executor import ExecConfig, Executor, ExecStats

__all__ = [
    "Table", "Column", "col", "lit", "isin", "between", "like", "Expr",
    "ExprValue", "is_null", "is_not_null", "coalesce",
    "ops", "Scan", "Join", "GroupBy", "Project", "Sort", "Limit",
    "SubqueryScan", "PlanNode", "ExecConfig", "Executor", "ExecStats",
]
