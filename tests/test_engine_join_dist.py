"""Distributed join runtime (`repro.core.engine_join_dist`):

* property suite: hash-partition + all-to-all + local join (and the
  broadcast-build strategy) over 1/2/4/8 shards is bit-exact with the
  single-host `sorted_join_indices` reference — all `how` modes,
  duplicate keys, negative keys, empty sides;
* NULL-key (-1 cursor slot) propagation through distributed joins vs
  the single-host path;
* bit-exactness of all 20 TPC-H query results for
  `Executor(engine="distributed")` against the single-host oracle —
  simulated shards on one XLA device, real `shard_map` collectives when
  the session has more (the CI multi-device job).

The real-device exchange is additionally covered in
tests/test_distributed.py via subprocesses with forced host devices.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # property tests skip, rest run
    HAVE_HYPOTHESIS = False

    def given(*a, **kw):                # no-op decorators keep the
        return lambda f: pytest.mark.skip("hypothesis missing")(f)

    def settings(*a, **kw):             # module importable without it
        return lambda f: f

    class st:                           # strategies resolved lazily at
        def __getattr__(self, name):    # decoration time only
            raise AttributeError(name)

        @staticmethod
        def lists(*a, **kw):
            return None

        @staticmethod
        def integers(*a, **kw):
            return None

        @staticmethod
        def sampled_from(*a, **kw):
            return None

from repro.core.engine_join import (  # noqa: E402
    NumpyJoinEngine, sorted_join_indices,
)
from repro.core.engine_join_dist import (  # noqa: E402
    DistributedJoinEngine, SimulatedExchange, broadcast_join_indices,
    get_distributed_engine, shard_bounds, shard_cursor,
    shuffle_join_indices,
)
from repro.relational import Executor, Table, col  # noqa: E402
from repro.relational.plan import Join, Scan  # noqa: E402
from repro.tpch import QUERIES, build_query  # noqa: E402

HOWS = ("inner", "left", "semi", "anti")
SHARDS = (1, 2, 4, 8)

keys = st.lists(st.integers(min_value=-4, max_value=14),
                min_size=0, max_size=60)


def _assert_matches_reference(bk, pk, how, nshards):
    eb, ep = sorted_join_indices(bk, pk, how)
    ex = SimulatedExchange(nshards)
    if len(bk) and len(pk) and nshards > 1:
        gb, gp, wire = shuffle_join_indices(bk, pk, how, ex)
        np.testing.assert_array_equal(gb, eb,
                                      err_msg=f"shuffle/{how}/{nshards}")
        np.testing.assert_array_equal(gp, ep,
                                      err_msg=f"shuffle/{how}/{nshards}")
        assert wire >= 0
    gb, gp, _ = broadcast_join_indices(bk, pk, how, ex,
                                       NumpyJoinEngine())
    np.testing.assert_array_equal(gb, eb,
                                  err_msg=f"broadcast/{how}/{nshards}")
    np.testing.assert_array_equal(gp, ep,
                                  err_msg=f"broadcast/{how}/{nshards}")


@settings(max_examples=60, deadline=None)
@given(keys, keys, st.sampled_from(HOWS), st.sampled_from(SHARDS))
def test_shuffle_and_broadcast_match_reference(a, b, how, nshards):
    """Duplicate-heavy small-domain keys: every strategy must reproduce
    the single-host reference over any shard count."""
    _assert_matches_reference(np.array(a, np.int64),
                              np.array(b, np.int64), how, nshards)


def test_strategies_match_reference_deterministic():
    """Hypothesis-free mirror of the property test (runs everywhere)."""
    rng = np.random.default_rng(0)
    for _ in range(25):
        nb, npr = int(rng.integers(0, 120)), int(rng.integers(0, 160))
        bk = rng.integers(-5, 30, nb).astype(np.int64)
        pk = rng.integers(-5, 35, npr).astype(np.int64)
        for how in HOWS:
            for p in SHARDS:
                _assert_matches_reference(bk, pk, how, p)


def test_engine_strategy_choice_and_byte_accounting():
    """Small build => broadcast (transfer-shrunk dimension case), big
    symmetric build => shuffle; wire bytes land in the right counter."""
    eng = DistributedJoinEngine(nshards=4, device=False)
    small_b = np.arange(10, dtype=np.int64)
    big_p = np.arange(10_000, dtype=np.int64) % 10
    eng.join_indices(small_b, big_p, "inner")
    assert eng.stats.joins[-1].strategy == "broadcast"
    assert eng.stats.joins[-1].broadcast_bytes == 3 * 10 * 8
    assert eng.stats.joins[-1].shuffle_bytes == 0

    big_b = np.arange(8_000, dtype=np.int64)
    eng.join_indices(big_b, big_p, "inner")
    assert eng.stats.joins[-1].strategy == "shuffle"
    assert eng.stats.joins[-1].shuffle_bytes > 0
    assert eng.stats.joins[-1].broadcast_bytes == 0

    eng.join_indices(np.array([], np.int64), big_p, "inner")
    assert eng.stats.joins[-1].strategy == "local"
    assert eng.stats.strategy_counts() == {"broadcast": 1, "shuffle": 1,
                                           "local": 1}


def test_forked_engines_share_exchange_but_not_stats():
    a = get_distributed_engine(4, device=False)
    b = get_distributed_engine(4, device=False)
    assert a.exchange is b.exchange
    a.join_indices(np.arange(5, dtype=np.int64),
                   np.arange(9, dtype=np.int64), "inner")
    assert len(a.stats.joins) == 1 and len(b.stats.joins) == 0


def test_shard_bounds_cover_and_stay_contiguous():
    for n in (0, 1, 7, 64, 1000):
        for p in SHARDS:
            b = shard_bounds(n, p)
            assert b[0] == 0 and b[-1] == n
            assert (np.diff(b) >= 0).all()
            assert int(np.diff(b).sum()) == n


# --------------------------------------------------------------------------
# cursor-level: NULL slots, sharding invariant, full plans
# --------------------------------------------------------------------------


def _assert_tables_exact(a: Table, b: Table, ctx):
    assert a.names == b.names, ctx
    assert len(a) == len(b), (ctx, len(a), len(b))
    for n in a.names:
        va = a[n].valid if a[n].valid is not None \
            else np.ones(len(a), bool)
        vb = b[n].valid if b[n].valid is not None \
            else np.ones(len(b), bool)
        np.testing.assert_array_equal(va, vb, err_msg=str((ctx, n)))
        np.testing.assert_array_equal(a[n].data[va], b[n].data[vb],
                                      err_msg=str((ctx, n)))


def test_null_cursor_slots_through_distributed_joins():
    """A left join's -1 cursor slots flow into a second, distributed
    join: NULL keys must never match, identically to the single-host
    runtime, for every second-join mode and shard count."""
    cat = {
        "ta": Table.from_arrays({"a": np.arange(40, dtype=np.int64),
                                 "k": np.arange(40, dtype=np.int64) * 3},
                                "ta"),
        "tb": Table.from_arrays({"k2": np.arange(0, 60, 2,
                                                 dtype=np.int64),
                                 "b": np.arange(30, dtype=np.int64)},
                                "tb"),
        "td": Table.from_arrays({"b2": np.arange(0, 30, 3,
                                                 dtype=np.int64),
                                 "d": np.arange(10, dtype=np.int64) * 7},
                                "td"),
    }
    for how2 in HOWS:
        plan = Join(Join(Scan("ta"), Scan("tb", filter=col("b") < 20),
                         ["k"], ["k2"], how="left"),
                    Scan("td"), ["b"], ["b2"], how=how2)
        ref, _ = Executor(cat).execute(plan)
        for p in (2, 4):
            got, stats = Executor(cat, engine="distributed",
                                  dist_shards=p,
                                  dist_device=False).execute(plan)
            _assert_tables_exact(ref, got, (how2, p))
            assert stats.dist is not None


@settings(max_examples=30, deadline=None)
@given(keys, keys, keys, st.sampled_from(HOWS), st.sampled_from(HOWS),
       st.sampled_from((2, 4, 8)))
def test_distributed_composition_matches_single_host(ka, kb, kc, how1,
                                                     how2, nshards):
    """(A ⋈ B) ⋈ C with random modes, including the duplicate-key and
    left-join NULL-slot cases, over random shard counts."""
    cat = {
        "ta": Table.from_arrays({"a_key": np.array(ka, np.int64),
                                 "a_val": np.arange(len(ka)) * 10}, "ta"),
        "tb": Table.from_arrays({"b_key": np.array(kb, np.int64),
                                 "b_val": np.arange(len(kb)) * 100}, "tb"),
        "tc": Table.from_arrays({"c_key": np.array(kc, np.int64),
                                 "c_val": np.arange(len(kc)) * 7}, "tc"),
    }
    on2 = "a_key" if how1 in ("semi", "anti") else "b_key"
    plan = Join(Join(Scan("ta"), Scan("tb"), ["a_key"], ["b_key"],
                     how=how1),
                Scan("tc"), [on2], ["c_key"], how=how2)
    ref, _ = Executor(cat).execute(plan)
    got, _ = Executor(cat, engine="distributed", dist_shards=nshards,
                      dist_device=False).execute(plan)
    _assert_tables_exact(ref, got, (how1, how2, nshards))


def test_shard_cursor_materialization_invariant(tpch_small):
    """Materializing the per-shard cursors in shard order and stacking
    equals materializing the host-mirror cursor whole — the invariant
    that lets survivors stay sharded until the first value-needing
    operator."""
    from repro.core.engine_join import JoinCursor, Slot
    from repro.relational import ops

    lineitem = tpch_small["lineitem"]
    orders = tpch_small["orders"]
    cur = JoinCursor.from_slot(Slot(lineitem))
    bidx, pidx = ops.join_indices_nullsafe(
        ops.composite_key(orders, ["o_orderkey"]),
        ops.composite_key(lineitem, ["l_orderkey"]), how="inner")
    cur = JoinCursor.join(cur, JoinCursor.from_slot(Slot(orders)),
                          bidx, pidx, "inner")
    whole, _ = cur.materialize(["l_orderkey", "o_totalprice"])
    for p in (2, 8):
        shards = shard_cursor(cur, p)
        assert sum(len(s) for s in shards) == len(cur)
        parts = [s.materialize(["l_orderkey", "o_totalprice"])[0]
                 for s in shards]
        for name in whole.names:
            np.testing.assert_array_equal(
                whole[name].data,
                np.concatenate([t[name].data for t in parts]),
                err_msg=(name, p))


# --------------------------------------------------------------------------
# TPC-H: all 20 queries bit-exact vs the single-host oracle
# --------------------------------------------------------------------------


@pytest.mark.parametrize("qn", sorted(QUERIES))
def test_tpch_distributed_engine_bit_exact(tpch_small, qn):
    """Simulated shards on a single-device session; real `shard_map`
    collectives when the session was launched with forced host devices
    (the CI multi-device job runs this file under
    XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
    ref, _ = Executor(tpch_small).execute(build_query(qn, sf=0.01))
    got, stats = Executor(tpch_small, engine="distributed").execute(
        build_query(qn, sf=0.01))
    _assert_tables_exact(ref, got, qn)
    assert stats.dist is not None and stats.dist.nshards >= 2
    assert stats.dist.joins, "no joins routed through the runtime"


def test_tpch_q5_records_wire_bytes(tpch_small):
    _, stats = Executor(tpch_small, engine="distributed").execute(
        build_query(5, sf=0.01))
    d = stats.dist
    assert d.shuffle_bytes + d.broadcast_bytes > 0
    counts = d.strategy_counts()
    assert counts.get("broadcast", 0) + counts.get("shuffle", 0) > 0
