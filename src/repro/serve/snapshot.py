"""Warm-restart cache snapshots (DESIGN.md §16).

A drained `QueryServer` can serialize its cache tier — artifact cache,
plan cache, selectivity history — to one file, and a freshly
constructed server can absorb it so the *first* post-restart query
replays warm instead of recomputing every filter and slot state.

The hard part is identity. Cache keys embed `Table.version` numbers,
which are process-local counters — a restarted process builds the same
catalog under different numbers, and blindly reusing snapshot entries
would marry artifacts to the wrong data. Restore therefore re-verifies
provenance end to end:

1. **File integrity** — the payload travels behind a magic header and
   an md5 signature; a mismatch (bit rot, truncation, an injected
   ``snapshot.load`` fault) drops the whole snapshot and the server
   starts cold. Corruption is a counted, non-fatal event.
2. **Catalog identity** — the snapshot records every referenced
   catalog table's ``(version, table_digest)``. A current catalog
   table whose digest matches **re-adopts** the snapshot's version
   number (after `bump_version_floor` guarantees the number can never
   be handed out again, and any unrelated table squatting on it is
   re-versioned first); a table that changed — or disappeared —
   invalidates every entry derived from its recorded version.
3. **Entry integrity** — each artifact's stored content checksum is
   recomputed on absorb (`ArtifactCache.absorb`); rows whose bytes no
   longer match are dropped and counted, never served.

Within one process (drain → restart the server object) versions
already match and steps 2–3 degenerate to cheap equality checks; the
digest walk is what makes the cross-process path safe.
"""
from __future__ import annotations

import hashlib
import io
import os
import pickle
from typing import Mapping, Optional

from repro.core import faultinject

#: file magic; bump when the payload layout changes (older snapshots
#: are then dropped as corrupt — a cold start, never a crash)
_MAGIC = b"RSNAP1\n"
FORMAT_VERSION = 1


def write_snapshot(path: str, catalog: Mapping[str, object],
                   artifact_cache=None, plan_cache=None,
                   sel_history=None) -> dict:
    """Serialize the cache tier to `path` (atomic rename). Returns
    counts of what was written."""
    from repro.relational.table import table_digest
    referenced = set()
    artifacts = artifact_cache.export_entries() \
        if artifact_cache is not None else []
    for row in artifacts:
        referenced |= set(row[3])          # versions
    plans = plan_cache.export_entries() if plan_cache is not None else []
    sels = sel_history.export_entries() if sel_history is not None else []
    for key, _ in list(plans) + list(sels):
        referenced |= {v for _, v in key[1]}   # cat_sig versions
    by_version = {t.version: name for name, t in catalog.items()}
    tables = {}
    for v in sorted(referenced):
        name = by_version.get(v)
        if name is not None:
            tables[name] = (v, table_digest(catalog[name]))
    doc = {
        "format": FORMAT_VERSION,
        "tables": tables,
        "artifacts": artifacts,
        "plans": plans,
        "sels": sels,
    }
    buf = io.BytesIO()
    pickle.dump(doc, buf, protocol=pickle.HIGHEST_PROTOCOL)
    payload = buf.getvalue()
    sig = hashlib.md5(payload).hexdigest().encode()
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(_MAGIC + sig + b"\n" + payload)
    os.replace(tmp, path)
    return {"path": path, "bytes": len(payload),
            "artifacts": len(artifacts), "plans": len(plans),
            "sels": len(sels), "tables": len(tables)}


def load_snapshot(path: str, catalog: Mapping[str, object],
                  artifact_cache=None, plan_cache=None,
                  sel_history=None) -> dict:
    """Absorb a snapshot into the given caches. Never raises for bad
    snapshots: any integrity failure reports ``loaded: False`` (cold
    start). Mutates matching catalog tables' `version` to the
    snapshot's recorded numbers (see module docstring) — call before
    serving any query from this catalog."""
    from repro.relational.table import bump_version_floor, table_digest
    out = {"loaded": False, "reason": None, "artifacts": 0,
           "artifacts_dropped": 0, "plans": 0, "sels": 0,
           "tables_matched": 0, "tables_stale": 0}
    try:
        with open(path, "rb") as f:
            raw = f.read()
        faultinject.fire("snapshot.load")
        if not raw.startswith(_MAGIC):
            out["reason"] = "bad-magic"
            return out
        head, _, payload = raw[len(_MAGIC):].partition(b"\n")
        if hashlib.md5(payload).hexdigest().encode() != head:
            out["reason"] = "signature-mismatch"
            return out
        doc = pickle.loads(payload)
        if doc.get("format") != FORMAT_VERSION:
            out["reason"] = f"format-{doc.get('format')!r}"
            return out
    except FileNotFoundError:
        out["reason"] = "missing"
        return out
    except Exception as e:                 # injected fault, bad pickle
        out["reason"] = f"corrupt:{type(e).__name__}"
        return out

    # -- catalog identity: re-adopt digest-verified versions -----------
    matched = {}                           # name -> snapshot version
    for name, (ver, digest) in doc["tables"].items():
        t = catalog.get(name)
        if t is not None and table_digest(t) == digest:
            matched[name] = int(ver)
        else:
            out["tables_stale"] += 1
    valid = set(matched.values())
    if doc["tables"]:
        bump_version_floor(max(v for v, _ in doc["tables"].values()))
    # move any unrelated current table off a number we are re-adopting
    # (fresh-process counters can collide across table identities)
    from repro.relational.table import _next_version
    for name, t in catalog.items():
        if t.version in valid and matched.get(name) != t.version:
            t.version = _next_version()
    for name, ver in matched.items():
        catalog[name].version = ver
    out["tables_matched"] = len(matched)

    def _versions_ok(versions) -> bool:
        return all(int(v) in valid for v in versions)

    if artifact_cache is not None:
        rows = [r for r in doc["artifacts"] if _versions_ok(r[3])]
        kept, dropped = artifact_cache.absorb(rows)
        out["artifacts"] = kept
        out["artifacts_dropped"] = (len(doc["artifacts"]) - len(rows)
                                    + dropped)
    if plan_cache is not None:
        rows = [(k, v) for k, v in doc["plans"]
                if _versions_ok(ver for _, ver in k[1])]
        out["plans"] = plan_cache.absorb(rows)
    if sel_history is not None:
        rows = [(k, v) for k, v in doc["sels"]
                if _versions_ok(ver for _, ver in k[1])]
        out["sels"] = sel_history.absorb(rows)
    out["loaded"] = True
    return out


def restore_if_present(path: Optional[str], catalog, artifact_cache=None,
                       plan_cache=None, sel_history=None) -> Optional[dict]:
    """`load_snapshot` if `path` names an existing file, else None."""
    if not path or not os.path.exists(path):
        return None
    return load_snapshot(path, catalog, artifact_cache=artifact_cache,
                         plan_cache=plan_cache, sel_history=sel_history)
