"""Forward passes for every block type in the zoo.

All functions are pure (params-first), jit/scan/shard_map friendly, and
support three execution modes:
  * train/prefill: full-sequence forward, optional KV/state cache output;
  * decode: q_len==1 step against a static-capacity cache.

Attention variants: GQA (optionally biased QKV — qwen), sliding-window
(mixtral/mistral), MLA latent-compressed KV (deepseek-v2), bidirectional
encoder + cross-attention (whisper). Sequence mixers: softmax attention
and Mamba-2 SSD (state-space duality, chunked block algorithm).

Numerics: matmuls in the param dtype (bf16), softmax/logits in fp32,
norms in fp32.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import AttnConfig, MambaConfig, ModelConfig
from repro.parallel import hints as HT

# --------------------------------------------------------------------------
# norms & basics
# --------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    nrm = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (nrm * w).astype(x.dtype)


def layernorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return (((xf - mu) * jax.lax.rsqrt(var + eps)) * w).astype(x.dtype)


def norm(x, w, kind: str):
    return rmsnorm(x, w) if kind == "rmsnorm" else layernorm(x, w)


def silu(x):
    return x * jax.nn.sigmoid(x)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope_tables(positions: jnp.ndarray, dim: int, theta: float):
    """positions [B, S] -> (cos, sin) [B, S, dim/2] fp32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv[None, None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x [B, S, H, D] with D even; rotate half (GPT-NeoX style)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# --------------------------------------------------------------------------
# softmax attention core
# --------------------------------------------------------------------------


# score-matrix entries above this trigger the chunked (flash-style) path
_SDPA_CHUNK_THRESHOLD = 4096 * 4096
_Q_CHUNK = 512
_KV_CHUNK = 1024


def _sdpa_dense(q, k, v, q_pos, kv_pos, kv_valid, *, causal, window):
    b, sq, h, d = q.shape
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    logits = logits / math.sqrt(d)
    mask = kv_valid[:, None, None, :]
    if causal:
        mask = mask & (kv_pos[:, None, None, :] <= q_pos[:, None, :, None])
    if window is not None:
        mask = mask & (q_pos[:, None, :, None] - kv_pos[:, None, None, :]
                       < window)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _sdpa_chunked(q, k, v, q_pos, kv_pos, kv_valid, *, causal, window):
    """Online-softmax attention, scanned over Q and KV chunks: peak score
    buffer is [B,H,Qc,Kc] regardless of sequence length (the pure-JAX
    flash formulation; XLA fuses the inner loop)."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    qc = min(_Q_CHUNK, sq)
    kc = min(_KV_CHUNK, skv)
    # pad to chunk multiples
    sq_p = -(-sq // qc) * qc
    skv_p = -(-skv // kc) * kc
    pad_q = sq_p - sq
    pad_k = skv_p - skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad_k)))
        kv_valid = jnp.pad(kv_valid, ((0, 0), (0, pad_k)))

    nq, nk = sq_p // qc, skv_p // kc
    qs = q.reshape(b, nq, qc, h, d).transpose(1, 0, 2, 3, 4)
    qp = q_pos.reshape(b, nq, qc).transpose(1, 0, 2)
    ks = k.reshape(b, nk, kc, k.shape[2], d)
    vs = v.reshape(b, nk, kc, v.shape[2], d)
    kp = kv_pos.reshape(b, nk, kc)
    kval = kv_valid.reshape(b, nk, kc)
    scale = 1.0 / math.sqrt(d)

    def q_step(_, qx):
        qi, qpi = qx                                   # [b,qc,h,d], [b,qc]

        def kv_step(carry, kx):
            acc, mx, lse = carry
            ki, vi, kpi, kvi = kx
            s = jnp.einsum("bqhd,bkhd->bhqk", qi, ki,
                           preferred_element_type=jnp.float32) * scale
            m = kvi[:, None, None, :]
            if causal:
                m = m & (kpi[:, None, None, :] <= qpi[:, None, :, None])
            if window is not None:
                m = m & (qpi[:, None, :, None] - kpi[:, None, None, :]
                         < window)
            s = jnp.where(m, s, -1e30)
            new_mx = jnp.maximum(mx, s.max(-1))
            alpha = jnp.exp(mx - new_mx)
            p = jnp.exp(s - new_mx[..., None])
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(qi.dtype), vi
            ).astype(jnp.float32)
            lse = lse * alpha + p.sum(-1)
            return (acc, new_mx, lse), None

        acc0 = jnp.zeros((b, h, qc, d), jnp.float32)
        mx0 = jnp.full((b, h, qc), -jnp.inf, jnp.float32)
        lse0 = jnp.zeros((b, h, qc), jnp.float32)
        (acc, mx, lse), _ = jax.lax.scan(
            kv_step, (acc0, mx0, lse0),
            (ks.transpose(1, 0, 2, 3, 4), vs.transpose(1, 0, 2, 3, 4),
             kp.transpose(1, 0, 2), kval.transpose(1, 0, 2)))
        out = acc / jnp.maximum(lse, 1e-30)[..., None]
        return None, out.transpose(0, 2, 1, 3).astype(qi.dtype)

    _, outs = jax.lax.scan(q_step, None, (qs, qp))     # [nq,b,qc,h,d]
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sq_p, h, d)
    return out[:, :sq]


# attention backend: "auto" (dense, chunked for long sequences) or
# "flash" (the Pallas kernel — TPU target; interpret-mode on CPU, so
# tests exercise it but CPU perf paths default to auto)
_SDPA_BACKEND = "auto"


def set_attention_backend(name: str) -> None:
    global _SDPA_BACKEND
    assert name in ("auto", "flash"), name
    _SDPA_BACKEND = name


def _sdpa(q, k, v, q_pos, kv_pos, kv_valid, *, causal: bool,
          window: Optional[int]):
    """q [B,Sq,H,D], k/v [B,Skv,KVH,D] (KVH divides H). fp32 softmax.
    Long sequences automatically take the chunked flash-style path."""
    if _SDPA_BACKEND == "flash":
        from repro.kernels.flashattn import flash_attention
        return flash_attention(q, k, v, q_pos, kv_pos, kv_valid,
                               causal=causal, window=window)
    h = q.shape[2]
    kvh = k.shape[2]
    rep = h // kvh
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    if q.shape[1] * k.shape[1] > _SDPA_CHUNK_THRESHOLD:
        return _sdpa_chunked(q, k, v, q_pos, kv_pos, kv_valid,
                             causal=causal, window=window)
    return _sdpa_dense(q, k, v, q_pos, kv_pos, kv_valid,
                       causal=causal, window=window)


class KVCache(NamedTuple):
    """Static-capacity *ring* cache. `index` counts tokens ever written;
    token at position p lives in slot p % cap. For full-attention layers
    cap >= tokens so the ring never wraps; for sliding-window layers
    cap == window and old tokens are overwritten (exactly the tokens the
    window mask would exclude)."""
    k: jnp.ndarray          # [B, cap, KVH, D]   (MLA: c_kv [B, cap, r])
    v: jnp.ndarray          # [B, cap, KVH, D]   (MLA: k_rope [B, cap, dr])
    index: jnp.ndarray      # scalar int32


def _cache_update(cache: KVCache, k_new, v_new) -> KVCache:
    """Ring write of S_new entries at the cursor."""
    cap = cache.k.shape[1]
    idx = cache.index
    s = k_new.shape[1]
    kd, vd = cache.k.dtype, cache.v.dtype
    if s >= cap:
        # keep only the last `cap` tokens, placed at slot pos % cap
        p0 = idx + s - cap
        k = jnp.roll(k_new[:, -cap:].astype(kd), p0 % cap, axis=1)
        v = jnp.roll(v_new[:, -cap:].astype(vd), p0 % cap, axis=1)
        return KVCache(k, v, idx + s)
    if s == 1:
        slot = idx % cap
        k = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new.astype(kd), slot, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new.astype(vd), slot, axis=1)
        return KVCache(k, v, idx + 1)
    slots = (idx + jnp.arange(s)) % cap
    k = cache.k.at[:, slots].set(k_new.astype(kd))
    v = cache.v.at[:, slots].set(v_new.astype(vd))
    return KVCache(k, v, idx + s)


def _ring_positions(index, cap: int, batch: int):
    """(kv_pos, kv_valid) for a ring cache whose cursor is `index`:
    slot j holds position index-1-((index-1-j) % cap), invalid if < 0."""
    j = jnp.arange(cap)
    kv_pos = index - 1 - ((index - 1 - j) % cap)
    kv_valid = kv_pos >= 0
    kv_pos = jnp.broadcast_to(kv_pos[None, :], (batch, cap))
    kv_valid = jnp.broadcast_to(kv_valid[None, :], (batch, cap))
    return kv_pos.astype(jnp.int32), kv_valid


def attention(p: Dict[str, jnp.ndarray], x: jnp.ndarray, a: AttnConfig,
              positions: jnp.ndarray, cache: Optional[KVCache] = None,
              norm_kind: str = "rmsnorm"
              ) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    """Pre-norm residual attention block. If `cache` is given, new KV are
    appended and attention runs against the whole cache (decode/chunked
    prefill); otherwise self-attention over x."""
    b, s, d = x.shape
    h = norm(x, p["ln"], norm_kind)
    if a.kv_lora_rank:
        return _mla_attention(p, x, h, a, positions, cache, norm_kind)

    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if a.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, s, a.num_heads, a.head_dim)
    k = k.reshape(b, s, a.num_kv_heads, a.head_dim)
    v = v.reshape(b, s, a.num_kv_heads, a.head_dim)
    cos, sin = rope_tables(positions, a.head_dim, a.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    layout = HT.attn_layout(a.num_heads, s)
    q, k, v = HT.hint_qkv(q, k, v, layout)

    if cache is None:
        kv_pos = positions
        kv_valid = jnp.ones(k.shape[:2], bool)
        out = _sdpa(q, k, v, positions, kv_pos, kv_valid,
                    causal=a.causal, window=a.sliding_window)
        new_cache = None
    else:
        new_cache = _cache_update(cache, k, v)
        cap = cache.k.shape[1]
        kv_pos, kv_valid = _ring_positions(new_cache.index, cap, b)
        out = _sdpa(q, new_cache.k.astype(q.dtype),
                    new_cache.v.astype(q.dtype), positions, kv_pos,
                    kv_valid, causal=a.causal, window=a.sliding_window)
    out = HT.hint_attn_out(out, layout)
    y = out.reshape(b, s, a.num_heads * a.head_dim) @ p["wo"]
    return x + y, new_cache


def _mla_attention(p, x, h, a: AttnConfig, positions, cache, norm_kind):
    """DeepSeek-V2 multi-head latent attention. The cache stores only the
    compressed c_kv (r) + shared k_rope (dr) per token — the memory win
    that defines MLA."""
    b, s, d = x.shape
    nh, hd, dr = a.num_heads, a.head_dim, a.rope_head_dim
    c_kv = h @ p["w_dkv"]                                   # [B,S,r]
    k_rope = (h @ p["w_kr"]).reshape(b, s, 1, dr)           # shared head
    cos, sin = rope_tables(positions, dr, a.rope_theta)
    k_rope = apply_rope(k_rope, cos, sin)

    q = (h @ p["wq"]).reshape(b, s, nh, hd)
    q_rope = (h @ p["w_qr"]).reshape(b, s, nh, dr)
    q_rope = apply_rope(q_rope, cos, sin)

    if cache is not None:
        cache = _cache_update(cache, c_kv, k_rope[:, :, 0, :])
        c_all = cache.k.astype(x.dtype)                     # [B,cap,r]
        kr_all = cache.v.astype(x.dtype)[:, :, None, :]     # [B,cap,1,dr]
        cap = c_all.shape[1]
        kv_pos, kv_valid = _ring_positions(cache.index, cap, b)
    else:
        c_all, kr_all = c_kv, k_rope
        kv_pos = positions
        kv_valid = jnp.ones((b, s), bool)

    skv = c_all.shape[1]
    k_nope = (c_all @ p["w_uk"]).reshape(b, skv, nh, hd)
    vv = (c_all @ p["w_uv"]).reshape(b, skv, nh, hd)

    # fold the decoupled-RoPE dims into the feature axis: softmax(q·k) with
    # q' = [q_nope ; q_rope], k' = [k_nope ; k_rope] equals the two-term
    # MLA logit sum exactly, and inherits the chunked long-context path.
    # (Naive expand of k_nope per head; the w_uk-absorb decode optimization
    # is a §Perf item.)
    qq = jnp.concatenate([q, q_rope], axis=-1)
    kk = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_all, (b, skv, nh, dr))], axis=-1)
    vpad = jnp.concatenate(
        [vv, jnp.zeros((b, skv, nh, dr), vv.dtype)], axis=-1)
    layout = HT.attn_layout(nh, s)
    qq, kk, vpad = HT.hint_qkv(qq, kk, vpad, layout)
    # pad v's feature dim so _sdpa's 1/sqrt(hd+dr) scale sees hd+dr dims
    out = _sdpa(qq, kk, vpad, positions, kv_pos, kv_valid,
                causal=a.causal, window=None)
    out = HT.hint_attn_out(out, layout)
    out = out[..., :hd]
    y = out.reshape(b, s, nh * hd) @ p["wo"]
    return x + y, cache


def cross_attention(p, x, enc_out, a: AttnConfig, norm_kind="rmsnorm"):
    """Decoder cross-attention (whisper): queries from x, KV from the
    encoder output (no RoPE, no mask)."""
    b, s, d = x.shape
    h = norm(x, p["ln_x"], norm_kind)
    q = (h @ p["wq"]).reshape(b, s, a.num_heads, a.head_dim)
    se = enc_out.shape[1]
    k = (enc_out @ p["wk"]).reshape(b, se, a.num_kv_heads, a.head_dim)
    v = (enc_out @ p["wv"]).reshape(b, se, a.num_kv_heads, a.head_dim)
    pos_q = jnp.zeros((b, s), jnp.int32)
    pos_k = jnp.zeros((b, se), jnp.int32)
    out = _sdpa(q, k, v, pos_q, pos_k, jnp.ones((b, se), bool),
                causal=False, window=None)
    y = out.reshape(b, s, a.num_heads * a.head_dim) @ p["wo"]
    return x + y


# --------------------------------------------------------------------------
# MLPs & MoE
# --------------------------------------------------------------------------


def mlp(p, x, act: str, norm_kind: str = "rmsnorm"):
    h = norm(x, p["ln"], norm_kind)
    if act == "swiglu":
        y = (silu(h @ p["w1"]) * (h @ p["w3"])) @ p["w2"]
    elif act == "relu2":                      # squared ReLU (nemotron)
        y = jnp.square(jax.nn.relu(h @ p["w1"])) @ p["w2"]
    else:
        y = jax.nn.gelu(h @ p["w1"]) @ p["w2"]
    return x + y


def moe(p, x, cfg: ModelConfig, norm_kind: str = "rmsnorm"):
    """Top-k routed experts, GShard-style group-limited capacity.

    Tokens are split into G groups (G = data-parallel ways when a mesh is
    ambient, so groups coincide with shards) and each group computes its
    expert capacities with a *local* cumsum — no cross-shard cumsum, so
    the dispatch tensors stay [G(data), Tg, E(model), C] sharded and the
    token->expert exchange lowers to an all-to-all. Overflow tokens fall
    back to the residual path. Shared experts (deepseek) run densely.
    """
    m = cfg.moe
    b, s, d = x.shape
    h = norm(x, p["ln"], norm_kind)
    t = b * s
    g = HT.dp_size()
    if t % g:
        g = 1
    tg = t // g
    htg = h.reshape(g, tg, d)
    htg = HT.hint(htg, "batch", None, None)

    logits = (htg.astype(jnp.float32) @ p["router"])         # [G,Tg,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, m.top_k)             # [G,Tg,k]
    top_w = top_w / jnp.clip(top_w.sum(-1, keepdims=True), 1e-9)

    if s == 1:
        # decode is dropless: one token per sequence, capacity = worst
        # case (all tokens in the group on one expert) — tiny anyway
        cap = tg
    else:
        cap = int(max(1, m.capacity_factor * tg * m.top_k
                      / m.num_experts))
    # per-group position of each (token, slot) within its expert
    onehot = jax.nn.one_hot(top_e, m.num_experts,
                            dtype=jnp.int32)                 # [G,Tg,k,E]
    flat = onehot.reshape(g, tg * m.top_k, m.num_experts)
    pos = jnp.cumsum(flat, axis=1) - flat                    # [G,Tg*k,E]
    pos = (pos * flat).sum(-1).reshape(g, tg, m.top_k)       # [G,Tg,k]
    keep = pos < cap
    w = top_w * keep

    # dispatch/combine as contractions over the top-k axis — never
    # materializes the [G,Tg,k,E,C] outer product
    oh_e = onehot.astype(htg.dtype) \
        * keep[..., None].astype(htg.dtype)                  # [G,Tg,k,E]
    oh_c = jax.nn.one_hot(pos, cap, dtype=htg.dtype)         # [G,Tg,k,C]
    dispatch = jnp.einsum("gtke,gtkc->gtec", oh_e, oh_c)     # [G,Tg,E,C]
    dispatch = HT.hint(dispatch, "batch", None, "model", None)
    xin = jnp.einsum("gtec,gtd->gecd", dispatch, htg)        # [G,E,C,d]
    xin = HT.hint(xin, "batch", "model", None, None)
    hmid = silu(jnp.einsum("gecd,edf->gecf", xin, p["w1"])) \
        * jnp.einsum("gecd,edf->gecf", xin, p["w3"])
    hmid = HT.hint(hmid, "batch", "model", None, None)
    xout = jnp.einsum("gecf,efd->gecd", hmid, p["w2"])       # [G,E,C,d]
    combine = jnp.einsum("gtke,gtkc->gtec", oh_e * w[..., None].astype(
        htg.dtype), oh_c)
    combine = HT.hint(combine, "batch", None, "model", None)
    y = jnp.einsum("gtec,gecd->gtd", combine, xout)

    if m.num_shared:
        sp = p["shared"]
        hs = norm(x, sp["ln"], norm_kind).reshape(t, d)
        y = y.reshape(t, d) \
            + (silu(hs @ sp["w1"]) * (hs @ sp["w3"])) @ sp["w2"]
    return x + y.reshape(b, s, d)


def moe_aux_loss(p, x, cfg: ModelConfig, norm_kind: str = "rmsnorm"):
    """Load-balancing auxiliary loss (Switch/GShard)."""
    m = cfg.moe
    h = norm(x, p["ln"], norm_kind)
    logits = h.reshape(-1, h.shape[-1]).astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_e = jnp.argmax(probs, -1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top_e, m.num_experts), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return m.num_experts * jnp.sum(frac_tokens * frac_probs)


# --------------------------------------------------------------------------
# Mamba-2 (SSD)
# --------------------------------------------------------------------------


class MambaCache(NamedTuple):
    conv: jnp.ndarray      # [B, d_conv-1, d_inner + 2*n] rolling window
    ssm: jnp.ndarray       # [B, H, P, N] state


def _segsum(x):
    """x [..., T] -> [..., T, T]; out[i,j] = sum_{l=j+1..i} x[l] (tril)."""
    T = x.shape[-1]
    xe = jnp.broadcast_to(x[..., :, None], (*x.shape, T))
    m1 = jnp.tril(jnp.ones((T, T), bool), -1)
    s = jnp.cumsum(jnp.where(m1, xe, 0.0), axis=-2)
    m2 = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(m2, s, -jnp.inf)


def _ssd_chunked(xh, dt, a_log, B, C, chunk: int):
    """SSD block-decomposition scan (Mamba-2 §6, ngroups=1).

    xh [b,s,h,p], dt [b,s,h] (post-softplus), a_log [h], B/C [b,s,n].
    Returns y [b,s,h,p], final_state [b,h,p,n].
    """
    b, s, hh, pp = xh.shape
    assert s % chunk == 0
    c = s // chunk
    A = -jnp.exp(a_log.astype(jnp.float32))                  # [h]
    dA = dt * A[None, None, :]                               # [b,s,h]
    xd = xh * dt[..., None].astype(xh.dtype)                 # dt-weighted x

    r = lambda t: t.reshape(b, c, chunk, *t.shape[2:])
    Xc, Ac, Bc, Cc = r(xd), r(dA), r(B), r(C)
    Ac = jnp.moveaxis(Ac, -1, 1)                             # [b,h,c,l]
    A_cum = jnp.cumsum(Ac, axis=-1)

    L = jnp.exp(_segsum(Ac))                                 # [b,h,c,l,l]
    Y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp",
                        Cc.astype(jnp.float32), Bc.astype(jnp.float32),
                        L, Xc.astype(jnp.float32))

    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)          # [b,h,c,l]
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn",
                        Bc.astype(jnp.float32), decay_states,
                        Xc.astype(jnp.float32))              # [b,c,h,p,n]

    init = jnp.zeros_like(states[:, :1])
    states = jnp.concatenate([init, states], axis=1)         # [b,c+1,...]
    pad_cum = jnp.pad(A_cum[..., -1], ((0, 0), (0, 0), (1, 0)))
    decay_chunk = jnp.exp(_segsum(pad_cum))                  # [b,h,c+1,c+1]
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, states)
    states, final = new_states[:, :-1], new_states[:, -1]

    state_decay = jnp.exp(A_cum)                             # [b,h,c,l]
    Y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp",
                       Cc.astype(jnp.float32), states, state_decay)
    y = (Y_diag + Y_off).reshape(b, s, hh, pp)
    return y.astype(xh.dtype), final


def mamba2(p, x, mb: MambaConfig, cache: Optional[MambaCache] = None,
           norm_kind: str = "rmsnorm"
           ) -> Tuple[jnp.ndarray, Optional[MambaCache]]:
    """Mamba-2 mixer block (pre-norm residual). cache => single-step decode."""
    b, s, d = x.shape
    d_inner = mb.expand * d
    nheads = d_inner // mb.head_dim
    n = mb.d_state
    h = norm(x, p["ln"], norm_kind)
    zxbcdt = h @ p["in_proj"]
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + d_inner + 2 * n]
    dt_raw = zxbcdt[..., -nheads:]

    # full-sequence path (train / whole-prompt prefill: any incoming cache
    # is treated as output-only — prefill starts from zero state);
    # s == 1 with a cache is the recurrent decode step.
    if cache is None or s > 1:
        # causal depthwise conv over the xBC stream
        pad = jnp.zeros((b, mb.d_conv - 1, xbc.shape[-1]), xbc.dtype)
        xbc_pad = jnp.concatenate([pad, xbc], axis=1)
        new_conv = xbc_pad[:, -(mb.d_conv - 1):, :] if mb.d_conv > 1 else \
            jnp.zeros((b, 0, xbc.shape[-1]), xbc.dtype)
        # causal depthwise conv as k shifted multiply-adds (no gather)
        acc = jnp.zeros_like(xbc)
        for kk in range(mb.d_conv):
            acc = acc + xbc_pad[:, kk:kk + s, :] \
                * p["conv_w"][kk][None, None, :].astype(xbc.dtype)
        xbc = silu(acc)
        xh = xbc[..., :d_inner].reshape(b, s, nheads, mb.head_dim)
        B = xbc[..., d_inner:d_inner + n]
        C = xbc[..., d_inner + n:]
        # SSD state/decay tensors are per-head: shard heads over TP so the
        # [b,h,c,l,l] intra-chunk decay matrix splits 16-way
        xh = HT.hint(xh, "batch", None, "model", None)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                             + p["dt_bias"][None, None, :])
        dt = HT.hint(dt, "batch", None, "model")
        pad_len = (-s) % mb.chunk
        if pad_len:
            zpad = lambda t: jnp.pad(t, [(0, 0), (0, pad_len)]
                                     + [(0, 0)] * (t.ndim - 2))
            y, final = _ssd_chunked(zpad(xh), zpad(dt), p["a_log"],
                                    zpad(B), zpad(C), mb.chunk)
            y = y[:, :s]
        else:
            y, final = _ssd_chunked(xh, dt, p["a_log"], B, C, mb.chunk)
        new_cache = MambaCache(new_conv, final)  # prefill -> decode handoff
    else:
        # single-token recurrent step
        xbc_win = jnp.concatenate([cache.conv, xbc], axis=1)  # [b,k,ch]
        new_conv = xbc_win[:, 1:, :]
        xbc1 = silu(jnp.einsum("bkc,kc->bc", xbc_win,
                               p["conv_w"].astype(xbc.dtype)))
        xh = xbc1[:, :d_inner].reshape(b, nheads, mb.head_dim)
        B = xbc1[:, d_inner:d_inner + n]
        C = xbc1[:, d_inner + n:]
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                             + p["dt_bias"][None, :])         # [b,h]
        A = -jnp.exp(p["a_log"].astype(jnp.float32))
        dA = jnp.exp(dt * A[None, :])                         # [b,h]
        hstate = cache.ssm * dA[..., None, None] \
            + (dt[..., None, None] * xh.astype(jnp.float32)[..., None]
               * B.astype(jnp.float32)[:, None, None, :])
        hstate = HT.hint(hstate, "batch", "model", None, None)
        y = jnp.einsum("bhpn,bn->bhp", hstate,
                       C.astype(jnp.float32))                 # [b,h,p]
        y = y[:, None].astype(x.dtype).reshape(b, 1, nheads, mb.head_dim)
        new_cache = MambaCache(new_conv, hstate)

    y = y.reshape(b, s, d_inner) + (p["d_skip"].astype(x.dtype)
                                    [None, None, :, None]
                                    * xh.reshape(b, s, nheads, mb.head_dim)
                                    ).reshape(b, s, d_inner)
    y = y * silu(z)
    return x + y @ p["out_proj"], new_cache
