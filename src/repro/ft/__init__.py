from repro.ft.runner import FaultTolerantTrainer, StragglerMonitor, Preempted

__all__ = ["FaultTolerantTrainer", "StragglerMonitor", "Preempted"]
