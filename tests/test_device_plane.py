"""Device-resident data plane (DESIGN.md §15):

* `DeviceStats` accounting: crossings count only inside `track()`,
  round trips total the h2d + d2h syncs, subquery merge adds through.
* Fused vertex scans (jax + pallas-interpret) vs the numpy host oracle:
  probe -> min-max range cut -> key-range -> build over one survivor
  set, filter words and masks bit-exact.
* The device sorted-segment join vs the engine NULL-contract reference
  (`JoinEngine.join_indices_valid`): a deterministic seeded sweep that
  always runs (duplicate keys, NULL keys on both sides, empty survivor
  sets, signed-extreme keys, all `how` modes) plus a hypothesis
  strategy when the package is present.
* TPC-H: all 20 queries bit-exact with the device plane forced on
  (jax at sf 0.01 under pred-trans and pred-trans-adaptive,
  pallas-interpret at sf 0.002), and the aggregate host<->device
  round-trip count must beat the legacy per-op path on the wide-join
  queries.
* Artifact-cache eviction: cost-to-rebuild weighting (cheap and
  unknown-cost artifacts go first, ties keep LRU order).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # property tests skip, rest run
    HAVE_HYPOTHESIS = False

    def given(*a, **kw):
        return lambda f: pytest.mark.skip("hypothesis missing")(f)

    def settings(*a, **kw):
        return lambda f: f

    class st:
        @staticmethod
        def lists(*a, **kw):
            return None

        @staticmethod
        def integers(*a, **kw):
            return None

        @staticmethod
        def sampled_from(*a, **kw):
            return None

        @staticmethod
        def booleans():
            return None

from repro.core import bloom, device_plane  # noqa: E402
from repro.core.artifact_cache import ArtifactCache  # noqa: E402
from repro.core.engine_bloom import get_engine  # noqa: E402
from repro.core.engine_join import NumpyJoinEngine  # noqa: E402
from repro.core.transfer import make_strategy  # noqa: E402
from repro.kernels.semijoin import ops as sj  # noqa: E402
from repro.relational import ExecConfig, Executor  # noqa: E402
from repro.tpch import QUERIES, build_query  # noqa: E402

HOWS = ("inner", "left", "semi", "anti")


def _assert_tables_exact(a, b, ctx):
    """Bitwise equality of all observable values (NULL rows'
    representative payload bytes are unspecified and excluded)."""
    assert a.names == b.names, ctx
    assert len(a) == len(b), (ctx, len(a), len(b))
    for n in a.names:
        va = a[n].valid if a[n].valid is not None \
            else np.ones(len(a), bool)
        vb = b[n].valid if b[n].valid is not None \
            else np.ones(len(b), bool)
        np.testing.assert_array_equal(va, vb, err_msg=str((ctx, n)))
        np.testing.assert_array_equal(a[n].data[va], b[n].data[vb],
                                      err_msg=str((ctx, n)))


# --------------------------------------------------------------------------
# DeviceStats accounting
# --------------------------------------------------------------------------


def test_device_stats_counts_only_inside_track():
    stats = device_plane.DeviceStats()
    a = np.arange(1024, dtype=np.int64)
    with device_plane.track(stats):
        d = device_plane.to_device(a)           # host -> device: counted
        device_plane.to_device(d)               # already device: free
        h = device_plane.to_host(d)             # device -> host: counted
        device_plane.to_host(h)                 # already host: free
    assert stats.h2d_syncs == 1
    assert stats.h2d_bytes == a.nbytes
    assert stats.d2h_syncs == 1
    assert stats.round_trips() == 2             # total crossings
    device_plane.to_device(a)                   # outside track(): free
    assert stats.h2d_syncs == 1


def test_device_stats_merge_and_report():
    a, b = device_plane.DeviceStats(), device_plane.DeviceStats()
    with device_plane.track(a):
        device_plane.to_device(np.zeros(8, np.int64))
        device_plane.count_fused()
    with device_plane.track(b):
        device_plane.to_host(device_plane.to_device(np.zeros(4, np.int64)))
        device_plane.count_compaction()
    a.merge(b)
    rep = a.report()
    assert rep["h2d_syncs"] == 2
    assert rep["d2h_syncs"] == 1
    assert rep["round_trips"] == 3              # h2d + d2h
    assert rep["fused_calls"] == 1
    assert rep["device_compactions"] == 1


def test_track_restores_previous_context():
    outer, inner = device_plane.DeviceStats(), device_plane.DeviceStats()
    with device_plane.track(outer):
        with device_plane.track(inner):
            device_plane.to_device(np.zeros(2, np.int64))
        device_plane.to_device(np.zeros(2, np.int64))
    assert inner.h2d_syncs == 1
    assert outer.h2d_syncs == 1


# --------------------------------------------------------------------------
# fused vertex scans: device backends vs the numpy host oracle
# --------------------------------------------------------------------------


def _scan_outputs(backend, mask, keys, keys2, raw, out_keys, valid,
                  words1, words2, nblocks):
    eng = get_engine(backend)
    scan = eng.begin(mask)
    scan.probe([(words1, eng.keys(keys)), (words2, eng.keys(keys2))])
    after_probe = np.asarray(device_plane.to_host(scan.mask)).copy()
    live_after = list(scan.live_after)
    scan.probe_range(raw, -120, 340, ek=eng.keys(raw))
    kr = scan.key_range(raw, ek=eng.keys(raw))
    krv = scan.key_range(raw, ek=eng.keys(raw), valid=valid)
    words = scan.build(eng.keys(out_keys), nblocks, valid=valid)
    return {"after_probe": after_probe, "live_after": live_after,
            "mask": np.asarray(device_plane.to_host(scan.mask)).copy(),
            "live": int(scan.live), "key_range": kr,
            "key_range_valid": krv,
            "words": np.asarray(device_plane.to_host(words)).copy()}


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_fused_scan_matches_numpy_oracle(rng, backend):
    """One fused probe->range-cut->build scan, bit-exact vs the host
    engine: surviving mask after each stage, per-filter live counts,
    device key ranges (plain and NULL-masked), emitted filter words."""
    n = 3000 if backend == "jax" else 600
    keys = rng.integers(0, 900, n).astype(np.int64)
    keys2 = rng.integers(0, 900, n).astype(np.int64)
    raw = rng.integers(-500, 500, n).astype(np.int64)
    out_keys = rng.integers(0, 900, n).astype(np.int64)
    mask = rng.random(n) < 0.8
    valid = rng.random(n) < 0.9
    nblocks = bloom.blocks_for(n)
    host = get_engine("numpy")
    words1 = np.asarray(host.build_filter(
        host.keys(rng.integers(0, 900, 500).astype(np.int64))).words)
    words2 = np.asarray(host.build_filter(
        host.keys(rng.integers(0, 900, 700).astype(np.int64))).words)
    args = (mask, keys, keys2, raw, out_keys, valid, words1, words2,
            nblocks)
    ref = _scan_outputs("numpy", *args)
    got = _scan_outputs(backend, *args)
    for field in ref:
        np.testing.assert_array_equal(
            np.asarray(got[field], dtype=object)
            if field.startswith("key_range") else got[field],
            np.asarray(ref[field], dtype=object)
            if field.startswith("key_range") else ref[field],
            err_msg=f"{backend}/{field}")


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_fused_scan_empty_survivors(rng, backend):
    """A disjoint range cut kills every row: the scan must report an
    empty live set, key_range None, and an all-zero outgoing filter —
    same as the host engine."""
    n = 256
    keys = rng.integers(0, 50, n).astype(np.int64)
    raw = rng.integers(0, 50, n).astype(np.int64)
    nblocks = bloom.blocks_for(n)
    outs = {}
    for b in ("numpy", backend):
        eng = get_engine(b)
        scan = eng.begin(np.ones(n, bool))
        scan.probe_range(raw, 1000, 2000, ek=eng.keys(raw))
        words = scan.build(eng.keys(keys), nblocks)
        outs[b] = (int(scan.live), scan.key_range(raw, ek=eng.keys(raw)),
                   np.asarray(device_plane.to_host(words)).copy())
    assert outs[backend][0] == outs["numpy"][0] == 0
    assert outs[backend][1] is None and outs["numpy"][1] is None
    np.testing.assert_array_equal(outs[backend][2], outs["numpy"][2])


# --------------------------------------------------------------------------
# device sorted-segment join vs the engine NULL-contract reference
# --------------------------------------------------------------------------


def _check_segjoin(bk, pk, how, bv=None, pv=None):
    eb, ep = NumpyJoinEngine().join_indices_valid(bk, pk, how, bv, pv)
    gb, gp = sj.segment_join_device(bk, pk, how, bv, pv)
    gb = np.asarray(device_plane.to_host(gb)).astype(np.int64)
    gp = np.asarray(device_plane.to_host(gp)).astype(np.int64)
    ctx = (how, len(bk), len(pk), bv is not None, pv is not None)
    np.testing.assert_array_equal(gb, eb, err_msg=str(ctx))
    np.testing.assert_array_equal(gp, ep, err_msg=str(ctx))


EXTREMES = np.array([np.iinfo(np.int64).min, -(1 << 62), -3, -1, 0, 1,
                     7, 1 << 31, (1 << 62) - 1, np.iinfo(np.int64).max],
                    np.int64)


@pytest.mark.parametrize("how", HOWS)
def test_segment_join_device_seeded_sweep(how):
    """Always-on property sweep: heavy duplicate keys, NULL keys on
    either side, signed-extreme key values."""
    rng = np.random.default_rng(42)
    for trial in range(25):
        nb = int(rng.integers(1, 70))
        npr = int(rng.integers(1, 90))
        if trial % 5 == 4:              # signed-extreme key mix
            bk = rng.choice(EXTREMES, nb)
            pk = rng.choice(EXTREMES, npr)
        else:
            dom = int(rng.integers(1, 14))
            bk = rng.integers(0, dom, nb).astype(np.int64)
            pk = rng.integers(0, dom, npr).astype(np.int64)
        bv = (rng.random(nb) < 0.75) if rng.random() < 0.5 else None
        pv = (rng.random(npr) < 0.75) if rng.random() < 0.5 else None
        _check_segjoin(bk, pk, how, bv, pv)


@pytest.mark.parametrize("how", HOWS)
def test_segment_join_device_empty_survivors(how):
    """All-NULL sides: no probe row may match; inner/semi emit nothing,
    left emits unmatched, anti keeps every live probe row."""
    bk = np.array([5, 5, 9], np.int64)
    pk = np.array([5, 9, 9, 11], np.int64)
    _check_segjoin(bk, pk, how, np.zeros(3, bool), None)
    _check_segjoin(bk, pk, how, None, np.zeros(4, bool))
    _check_segjoin(bk, pk, how, np.zeros(3, bool), np.zeros(4, bool))


def test_device_engine_empty_inputs_delegate():
    """The engine entry handles zero-length sides (the device kernel
    itself is only entered with rows on both sides)."""
    from repro.core.engine_join import get_join_engine
    eng = get_join_engine("jax", device_resident=True)
    for how in HOWS:
        for bk, pk in ((np.empty(0, np.int64), np.array([1], np.int64)),
                       (np.array([1], np.int64), np.empty(0, np.int64)),
                       (np.empty(0, np.int64), np.empty(0, np.int64))):
            eb, ep = NumpyJoinEngine().join_indices(bk, pk, how)
            gb, gp = eng.join_indices(bk, pk, how)
            np.testing.assert_array_equal(np.asarray(gb), eb)
            np.testing.assert_array_equal(np.asarray(gp), ep)


small_keys = st.lists(st.integers(min_value=-12, max_value=12),
                      min_size=1, max_size=40)


@settings(max_examples=50, deadline=None)
@given(small_keys, small_keys, st.sampled_from(HOWS),
       st.booleans(), st.booleans())
def test_hypothesis_segjoin_device_vs_reference(a, b, how, use_bv,
                                               use_pv):
    bk, pk = np.array(a, np.int64), np.array(b, np.int64)
    bv = (np.arange(len(bk)) % 3 != 0) if use_bv else None
    pv = (np.arange(len(pk)) % 2 == 0) if use_pv else None
    _check_segjoin(bk, pk, how, bv, pv)


# --------------------------------------------------------------------------
# TPC-H: bit-exactness with the device plane forced on + round-trip cut
# --------------------------------------------------------------------------


def _device_cfg(strategy, backend, device="on"):
    return ExecConfig(
        strategy=make_strategy(strategy, backend=backend,
                               device_resident=(device == "on")),
        join_backend=backend, device=device)


@pytest.mark.parametrize("strategy", ["pred-trans",
                                      "pred-trans-adaptive"])
@pytest.mark.parametrize("qn", sorted(QUERIES))
def test_tpch_device_plane_jax_bit_exact(tpch_small, qn, strategy):
    ref, _ = Executor(tpch_small,
                      ExecConfig(late_materialize=False)).execute(
        build_query(qn, sf=0.01))
    res, stats = Executor(tpch_small,
                          _device_cfg(strategy, "jax")).execute(
        build_query(qn, sf=0.01))
    _assert_tables_exact(ref, res, (qn, strategy))
    assert stats.report()["device"]["h2d_syncs"] > 0


@pytest.mark.parametrize("qn", sorted(QUERIES))
def test_tpch_device_plane_pallas_interpret_bit_exact(tpch_tiny, qn):
    """The full device plane with the pallas bloom engine in interpret
    mode, on the tiny catalog (interpret kernels run at Python speed)."""
    ref, _ = Executor(tpch_tiny,
                      ExecConfig(late_materialize=False)).execute(
        build_query(qn, sf=0.002))
    res, _ = Executor(tpch_tiny,
                      _device_cfg("pred-trans", "pallas")).execute(
        build_query(qn, sf=0.002))
    _assert_tables_exact(ref, res, qn)


def test_device_plane_cuts_round_trips(tpch_small):
    """On the widest join graphs the fused plane must beat the legacy
    per-op path on host<->device round trips — counts, not clocks, so
    this is deterministic. Both modes are counted through
    `device_plane`, so the comparison is symmetric."""
    tot = {"on": 0, "off": 0}
    for qn in (5, 8, 9, 21):
        digests = {}
        for mode in ("on", "off"):
            res, stats = Executor(tpch_small,
                                  _device_cfg("pred-trans", "jax",
                                              mode)).execute(
                build_query(qn, sf=0.01))
            rep = stats.report()["device"]
            assert set(rep) >= {"h2d_syncs", "h2d_bytes", "d2h_syncs",
                                "d2h_bytes", "round_trips",
                                "fused_calls", "device_compactions"}
            tot[mode] += rep["round_trips"]
            digests[mode] = res
        _assert_tables_exact(digests["on"], digests["off"], qn)
    assert tot["on"] < tot["off"], tot


def test_device_knob_validation():
    with pytest.raises(ValueError):
        ExecConfig(device="maybe")


# --------------------------------------------------------------------------
# artifact cache: cost-to-rebuild weighted eviction
# --------------------------------------------------------------------------


def test_eviction_prefers_cheap_over_old():
    c = ArtifactCache(max_bytes=100, verify_on_hit=False)
    c.put(("bloom", 1), b"a", 40, cost_ns=1_000_000)    # dear, oldest
    c.put(("bloom", 2), b"b", 40, cost_ns=10)           # cheap
    c.put(("bloom", 3), b"c", 40, cost_ns=1_000_000)    # forces evict
    assert c.get(("bloom", 2)) is None                  # cheap went
    assert c.get(("bloom", 1)) == b"a"                  # old+dear stays
    assert c.get(("bloom", 3)) == b"c"


def test_eviction_unknown_cost_goes_before_known():
    c = ArtifactCache(max_bytes=100, verify_on_hit=False)
    c.put(("bloom", 1), b"a", 40, cost_ns=5)
    c.put(("bloom", 2), b"b", 40)                       # unknown cost
    c.put(("bloom", 3), b"c", 40, cost_ns=5)
    assert c.get(("bloom", 2)) is None
    assert c.get(("bloom", 1)) == b"a"
    assert c.get(("bloom", 3)) == b"c"


def test_eviction_cost_density_is_per_byte():
    """A dear-per-artifact but cheap-per-byte entry loses to a small
    entry of equal cost: eviction frees the most bytes per rebuild-ns."""
    c = ArtifactCache(max_bytes=100, verify_on_hit=False)
    c.put(("bloom", 1), b"a", 80, cost_ns=1000)         # density 12.5
    c.put(("bloom", 2), b"b", 10, cost_ns=1000)         # density 100
    c.put(("bloom", 3), b"c", 20, cost_ns=1000)         # forces evict
    assert c.get(("bloom", 1)) is None
    assert c.get(("bloom", 2)) == b"b"
    assert c.get(("bloom", 3)) == b"c"


def test_eviction_tie_keeps_lru_order():
    c = ArtifactCache(max_bytes=100, verify_on_hit=False)
    c.put(("bloom", 1), b"a", 40, cost_ns=7)
    c.put(("bloom", 2), b"b", 40, cost_ns=7)
    c.get(("bloom", 1))                                 # refresh 1
    c.put(("bloom", 3), b"c", 40, cost_ns=7)            # forces evict
    assert c.get(("bloom", 2)) is None                  # LRU on tie
    assert c.get(("bloom", 1)) == b"a"
    assert c.get(("bloom", 3)) == b"c"
