"""Kernel microbenchmarks: ns/row for bloom build/probe/transfer and the
semijoin table, host path vs jnp path (the Pallas kernels are TPU-target;
interpret mode is not a performance proxy and is benchmarked only for
completeness at small n)."""
from __future__ import annotations

import time

import numpy as np


def _time(fn, *args, reps=3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    return (time.perf_counter() - t0) / reps, out


def run(n: int = 1_000_000):
    from repro.core import bloom
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 10**9, n).astype(np.int64)
    out_keys = keys * 7 + 3
    rows = []

    dt, f = _time(lambda: bloom.np_build(keys))
    rows.append(("bloom_build_numpy", dt / n * 1e9))
    filt = f
    dt, _ = _time(lambda: bloom.np_probe(filt, keys))
    rows.append(("bloom_probe_numpy", dt / n * 1e9))

    hk = bloom.hash_keys(keys)
    dt, _ = _time(lambda: bloom.hash_keys(keys))
    rows.append(("hash_keys_numpy", dt / n * 1e9))
    dt, _ = _time(lambda: bloom.probe_hashed(filt.words, hk))
    rows.append(("bloom_probe_hashed", dt / n * 1e9))
    live = np.zeros(n, bool)
    live[: n // 50] = True
    dt, _ = _time(lambda: bloom.probe_hashed(filt.words, hk, live=live))
    rows.append(("bloom_probe_hashed_2pct_live", dt / n * 1e9))

    import jax
    dt, _ = _time(lambda: jax.block_until_ready(
        bloom.np_build(keys, backend="jax").words))
    rows.append(("bloom_build_jnp", dt / n * 1e9))
    dt, _ = _time(lambda: bloom.np_probe(filt, keys, backend="jax"))
    rows.append(("bloom_probe_jnp", dt / n * 1e9))

    # precise membership (Yannakakis primitive) for the beta comparison
    from repro.relational.ops import semi_join_mask
    dt, _ = _time(lambda: semi_join_mask(keys, keys[: n // 2]))
    rows.append(("semijoin_sorted_numpy", dt / n * 1e9))
    return rows


def main(n: int = 1_000_000):
    rows = run(n)
    print("name,ns_per_row")
    for name, v in rows:
        print(f"{name},{v:.1f}")
    d = dict(rows)
    print(f"\nbeta (bloom probe / semijoin probe): "
          f"{d['bloom_probe_hashed'] / d['semijoin_sorted_numpy']:.2f}")
    return rows


if __name__ == "__main__":
    main()
