"""Fault-tolerant query execution (DESIGN.md §13).

Covers the four tentpole mechanisms end to end: QueryContext deadlines
and cross-thread cancellation (abort within one transfer pass), the
degradation ladder under every registered fault point (md5-bit-exact vs
the clean oracle), artifact-cache corruption self-heal, and the
pre-gather memory budget — plus the serving-layer satellites (worker
survival, metrics counters, deterministic shutdown).
"""
import threading
import time

import numpy as np
import pytest

from repro.core import faultinject
from repro.core.artifact_cache import ArtifactCache, content_checksum
from repro.core.errors import (
    BackendError, DeadlineExceeded, QueryCancelled, QueryContext,
    ResourceExhausted,
)
from repro.core.faultinject import FAULT_POINTS, FaultSchedule, InjectedFault
from repro.core.transfer import make_strategy
from repro.relational.executor import Executor
from repro.relational.plan import GroupBy, Join, Scan
from repro.relational.plancache import PlanCache
from repro.relational.table import Column, Table, table_digest
from repro.serve import QueryServer, ServeConfig
from repro.tpch import build_query

SF = 0.01


def _oracle(catalog, qn):
    ex = Executor(catalog, make_strategy("pred-trans"))
    return table_digest(ex.execute(build_query(qn, SF))[0])


def _small_catalog(n=5000, seed=0):
    rng = np.random.default_rng(seed)
    fact = Table({"f_k": Column(rng.integers(0, 100, n)),
                  "f_v": Column(rng.integers(0, 10, n))}, "fact")
    dim = Table({"d_k": Column(np.arange(100)),
                 "d_w": Column(rng.integers(0, 5, 100))}, "dim")
    return {"fact": fact, "dim": dim}


def _small_plan():
    return GroupBy(Join(Scan("fact"), Scan("dim"), ["f_k"], ["d_k"]),
                   ["d_w"], [("cnt", "count", None)])


# -------------------------------------------------------------------------
# QueryContext: deadlines + cancellation
# -------------------------------------------------------------------------


def test_deadline_pre_expired():
    cat = _small_catalog()
    ex = Executor(cat, make_strategy("pred-trans"))
    with pytest.raises(DeadlineExceeded) as ei:
        ex.execute(_small_plan(), ctx=QueryContext(timeout=-1.0))
    assert ei.value.phase == "scan"


def test_deadline_expires_mid_transfer():
    """An injectable counting clock expires the deadline after the
    scan-phase checks; the query must abort inside the transfer phase
    (per-pass/per-vertex checks), not run to completion."""
    cat = _small_catalog()
    calls = [0]

    def clock():
        calls[0] += 1
        return float(calls[0])

    # deadline at the 6th tick: scan-boundary checks pass, the
    # transfer pass loop trips it
    ctx = QueryContext(deadline=6.0, clock=clock)
    ex = Executor(cat, make_strategy("pred-trans"))
    with pytest.raises(DeadlineExceeded) as ei:
        ex.execute(_small_plan(), ctx=ctx)
    assert ei.value.phase == "transfer"


def test_deadline_aborts_within_one_pass(tpch_small):
    """Acceptance bar: a deadline below a query's known runtime aborts
    within one transfer pass. With a clock frozen past the deadline the
    very first post-scan check raises — zero passes complete."""
    now = time.monotonic()
    ctx = QueryContext(deadline=now - 1.0, tag="q9")
    ex = Executor(tpch_small, make_strategy("pred-trans"))
    t0 = time.perf_counter()
    with pytest.raises(DeadlineExceeded):
        ex.execute(build_query(9, SF), ctx=ctx)
    assert time.perf_counter() - t0 < 5.0
    assert ctx.phase in ("scan", "transfer")


def test_cancel_from_another_thread():
    """A clock that blocks mid-transfer hands control to a second
    thread, which cancels; the blocked query must then raise
    QueryCancelled at its next check (cancelled is checked before the
    deadline, so the far-future deadline never fires)."""
    cat = _small_catalog()
    reached = threading.Event()
    released = threading.Event()
    calls = [0]

    def clock():
        calls[0] += 1
        if calls[0] == 5:
            reached.set()
            assert released.wait(10)
        return 0.0

    ctx = QueryContext(deadline=1e9, tag="c", clock=clock)
    errs = []

    def run():
        ex = Executor(cat, make_strategy("pred-trans"))
        try:
            ex.execute(_small_plan(), ctx=ctx)
        except BaseException as e:   # noqa: BLE001
            errs.append(e)

    t = threading.Thread(target=run)
    t.start()
    assert reached.wait(10)
    ctx.cancel()
    released.set()
    t.join(10)
    assert len(errs) == 1 and isinstance(errs[0], QueryCancelled)


def test_query_context_remaining_and_tag():
    ctx = QueryContext(timeout=100.0, tag="t1")
    assert 0 < ctx.remaining() <= 100.0
    assert not ctx.cancelled
    assert QueryContext().remaining() is None


# -------------------------------------------------------------------------
# fault injection harness
# -------------------------------------------------------------------------


def test_fault_schedule_deterministic_and_counted():
    s = FaultSchedule({"join.indices": [1, 3]})
    with faultinject.inject(s):
        faultinject.fire("join.indices")                  # idx 0
        with pytest.raises(InjectedFault):
            faultinject.fire("join.indices")              # idx 1
        faultinject.fire("join.indices")                  # idx 2
        with pytest.raises(InjectedFault) as ei:
            faultinject.fire("join.indices")              # idx 3
        faultinject.fire("engine.probe")                  # unscheduled
    assert ei.value.point == "join.indices"
    assert s.calls["join.indices"] == 4 and s.fired["join.indices"] == 2
    faultinject.fire("join.indices")      # disarmed: no-op
    assert s.calls["join.indices"] == 4


def test_fault_schedule_seeded_reproducible():
    a = FaultSchedule.seeded(7, 0.5, points=("engine.probe",))
    b = FaultSchedule.seeded(7, 0.5, points=("engine.probe",))
    pat_a, pat_b = [], []
    for sched, pat in ((a, pat_a), (b, pat_b)):
        with faultinject.inject(sched):
            for _ in range(64):
                try:
                    faultinject.fire("engine.probe")
                    pat.append(0)
                except InjectedFault:
                    pat.append(1)
    assert pat_a == pat_b and 0 < sum(pat_a) < 64


def test_fault_schedules_do_not_nest():
    with faultinject.inject({"engine.probe": 0}):
        with pytest.raises(RuntimeError):
            with faultinject.inject({"engine.build": 0}):
                pass
    assert faultinject.active() is None


def test_injected_fault_is_backend_error():
    assert issubclass(InjectedFault, BackendError)


# -------------------------------------------------------------------------
# degradation ladder: every fault point, md5-bit-exact vs oracle
# -------------------------------------------------------------------------

# schedule per point + the rung move it must cause (see DESIGN.md §13).
# join.indices uses finite indices: the eager-oracle rung routes through
# the same numpy engine, so an "all" schedule would break every rung.
_POINT_CASES = [
    ("engine.probe", {"engine.probe": "all"}, "no-pred-trans"),
    ("engine.build", {"engine.build": "all"}, "no-pred-trans"),
    ("join.indices", {"join.indices": [0, 1]}, None),
    ("gather.payload", {"gather.payload": "all"}, None),
]


@pytest.mark.parametrize("point,spec,want_strategy",
                         [pytest.param(*c, id=c[0])
                          for c in _POINT_CASES])
def test_ladder_per_fault_point_bit_exact(tpch_small, point, spec,
                                          want_strategy):
    qn = 5
    want = _oracle(tpch_small, qn)
    ex = Executor(tpch_small, make_strategy("pred-trans"), degrade=True)
    with faultinject.inject(spec) as sched:
        result, stats = ex.execute(build_query(qn, SF))
    assert sched.total_fired() > 0, f"{point} never fired"
    assert stats.degraded, f"{point}: no ladder move recorded"
    assert stats.degraded[0]["phase"] == point
    assert table_digest(result) == want
    if want_strategy is not None:
        assert stats.strategy == want_strategy


def test_ladder_exchange_send_distributed(tpch_small):
    """exchange.send faults knock the distributed engine down to the
    single-host rung; the result stays bit-exact."""
    want = _oracle(tpch_small, 5)
    ex = Executor(tpch_small, make_strategy("pred-trans"),
                  engine="distributed", dist_shards=4, dist_device=False,
                  degrade=True)
    with faultinject.inject({"exchange.send": "all"}) as sched:
        result, stats = ex.execute(build_query(5, SF))
    assert sched.total_fired() > 0
    assert stats.degraded and stats.degraded[0]["from"].startswith(
        "distributed/")
    assert stats.degraded[0]["to"].startswith("single/")
    assert table_digest(result) == want


def test_ladder_adaptive_steps_to_pred_trans(tpch_small):
    """pred-trans-adaptive's first strategy rung is pred-trans, not
    straight to no-prefilter."""
    want = _oracle(tpch_small, 5)
    # force_apply: the cost gate may skip every edge at sf 0.01, and a
    # fault point that never fires cannot exercise the ladder
    ex = Executor(tpch_small,
                  make_strategy("pred-trans-adaptive",
                                mode="force_apply"),
                  degrade=True)
    with faultinject.inject({"engine.probe": "all"}):
        result, stats = ex.execute(build_query(5, SF))
    rungs = [d["to"].split("+")[1] for d in stats.degraded]
    assert rungs[0] == "pred-trans", rungs
    assert stats.strategy == "no-pred-trans"    # probes still faulting
    assert table_digest(result) == want


def test_no_degradation_without_opt_in(tpch_small):
    """degrade=False (the default) must propagate the fault — silent
    fallbacks would mask real engine bugs in oracle tests."""
    ex = Executor(tpch_small, make_strategy("pred-trans"))
    with faultinject.inject({"engine.probe": "all"}):
        with pytest.raises(InjectedFault):
            ex.execute(build_query(5, SF))


# -------------------------------------------------------------------------
# artifact cache: verify-on-hit + self-heal
# -------------------------------------------------------------------------


def test_cache_corruption_detected_and_dropped():
    ac = ArtifactCache()
    words = np.arange(64, dtype=np.uint32)
    ac.put(("bloom", b"sig"), (words, None), nbytes=words.nbytes)
    assert ac.get(("bloom", b"sig")) is not None
    words[3] ^= 0xFFFF                     # flip bits in place
    assert ac.get(("bloom", b"sig")) is None       # dropped, miss
    assert ac.corruptions == 1
    assert len(ac) == 0
    assert ac.snapshot()["corruptions"] == 1


def test_cache_deserialize_fault_counts_as_corruption():
    ac = ArtifactCache()
    ac.put(("bloom", b"x"), (np.ones(8, np.uint32), None), nbytes=32)
    with faultinject.inject({"cache.deserialize": 0}) as sched:
        assert ac.get(("bloom", b"x")) is None     # absorbed, not raised
    assert sched.fired["cache.deserialize"] == 1
    assert ac.corruptions == 1


def test_cache_self_heal_end_to_end(tpch_small):
    """Corrupt the stored slot entry's bytes; the warm rerun must
    detect it, recompute, and still be bit-exact."""
    want = _oracle(tpch_small, 5)
    ac, pc = ArtifactCache(), PlanCache()
    ex = Executor(tpch_small,
                  make_strategy("pred-trans", artifact_cache=ac),
                  plan_cache=pc, artifact_cache=ac)
    assert table_digest(ex.execute(build_query(5, SF))[0]) == want
    # flip bytes inside one stored slot table (entries are
    # (value, nbytes, versions, checksum); value = (slots, snap))
    key = next(k for k in ac._entries if k[0] == "slots")
    slots_entry = ac._entries[key][0][0]
    tbl = slots_entry[0][0]
    col = tbl[tbl.names[0]]
    col.data.flags.writeable = True
    col.data[0] += 1
    r2, s2 = ex.execute(build_query(5, SF))
    assert table_digest(r2) == want
    assert not s2.transfer.from_cache       # the hit was rejected
    assert ac.corruptions >= 1
    # healed: the rerun re-stored a good entry, next hit replays warm
    r3, s3 = ex.execute(build_query(5, SF))
    assert table_digest(r3) == want and s3.transfer.from_cache


def test_content_checksum_samples_large_arrays():
    big = np.zeros(1 << 20, np.int64)      # 8 MiB: sampled head+tail
    c1 = content_checksum(big)
    big[0] = 1                             # head sample sees this
    assert content_checksum(big) != c1
    t0 = time.perf_counter()
    for _ in range(10):
        content_checksum(big)
    assert (time.perf_counter() - t0) / 10 < 0.05   # O(1), not O(n)


def test_verify_on_hit_can_be_disabled():
    ac = ArtifactCache(verify_on_hit=False)
    words = np.arange(8, dtype=np.uint32)
    ac.put(("bloom", b"k"), (words, None), nbytes=32)
    words[0] ^= 1
    assert ac.get(("bloom", b"k")) is not None
    assert ac.corruptions == 0


# -------------------------------------------------------------------------
# memory budget
# -------------------------------------------------------------------------


def test_memory_budget_raises_without_degrade():
    cat = _small_catalog()
    ex = Executor(cat, make_strategy("pred-trans"),
                  mem_budget_bytes=100)
    with pytest.raises(ResourceExhausted) as ei:
        ex.execute(_small_plan())
    assert ei.value.phase == "join"


def test_memory_budget_degrades_eager_to_late():
    """A budget the eager path exceeds but the late path fits: the
    ladder switches materialization mode and stays bit-exact."""
    cat = _small_catalog()
    plan = _small_plan()
    want = table_digest(
        Executor(cat, make_strategy("pred-trans")).execute(plan)[0])
    _, se = Executor(cat, make_strategy("pred-trans"),
                     late_materialize=False).execute(plan)
    _, sl = Executor(cat, make_strategy("pred-trans")).execute(plan)
    assert sl.join_materialized_bytes < se.join_materialized_bytes
    budget = (sl.join_materialized_bytes
              + se.join_materialized_bytes) // 2
    ex = Executor(cat, make_strategy("pred-trans"),
                  late_materialize=False, degrade=True,
                  mem_budget_bytes=budget)
    result, stats = ex.execute(plan)
    assert stats.degraded and stats.degraded[0]["error"] == \
        "ResourceExhausted"
    assert "late" in stats.degraded[0]["to"]
    assert table_digest(result) == want


def test_memory_budget_from_context_overrides_executor():
    cat = _small_catalog()
    ex = Executor(cat, make_strategy("pred-trans"))
    with pytest.raises(ResourceExhausted):
        ex.execute(_small_plan(),
                   ctx=QueryContext(mem_budget_bytes=100))


# -------------------------------------------------------------------------
# serving layer: worker survival, counters, shutdown
# -------------------------------------------------------------------------


def test_worker_survives_failing_query(tpch_small):
    """A query that faults errors its own Future; the same worker then
    serves the next query."""
    cfg = ServeConfig(strategy="pred-trans", workers=1, degrade=False)
    with QueryServer(tpch_small, cfg) as srv:
        with faultinject.inject({"engine.probe": "all"}):
            fut = srv.submit(build_query(5, SF))
            with pytest.raises(InjectedFault):
                fut.result(30)
        want = _oracle(tpch_small, 5)
        assert table_digest(srv.query(build_query(5, SF))[0]) == want
        snap = srv.metrics_snapshot()["server"]
        assert snap["errors"] == 1 and snap["completed"] == 1


def test_server_degrades_by_default(tpch_small):
    want = _oracle(tpch_small, 5)
    with QueryServer(tpch_small,
                     ServeConfig(strategy="pred-trans",
                                 workers=1)) as srv:
        with faultinject.inject({"engine.probe": "all"}):
            result, stats = srv.query(build_query(5, SF))
        assert stats.degraded and table_digest(result) == want
        assert srv.metrics_snapshot()["server"]["degradations"] == 1


def test_server_timeout_and_cancel_counters(tpch_small):
    cfg = ServeConfig(strategy="pred-trans", workers=1)
    with QueryServer(tpch_small, cfg) as srv:
        with pytest.raises(DeadlineExceeded):
            srv.query(build_query(5, SF), timeout=0.0)
        # cancel a running query: stall the worker inside _execute
        # via a gate, flip the token, release
        gate = threading.Event()
        orig = srv._execute

        def gated(req):
            gate.wait(10)
            return orig(req)

        srv._execute = gated
        fut = srv.submit(build_query(5, SF))
        assert srv.cancel(fut) is True       # queued or running
        gate.set()
        with pytest.raises(BaseException):   # cancelled either way
            fut.result(30)
        snap = srv.metrics_snapshot()["server"]
        assert snap["timeouts"] == 1
        assert snap["cancellations"] + snap["failed"] >= 1


def test_close_resolves_all_futures(tpch_small):
    """Regression: close() must leave no Future permanently pending —
    queued requests behind a stalled worker are cancelled when
    cancel_pending=True."""
    cfg = ServeConfig(strategy="no-pred-trans", workers=1, max_queue=0)
    srv = QueryServer(tpch_small, cfg)
    gate = threading.Event()
    orig = srv._execute

    def stalled(req):
        gate.wait(20)
        return orig(req)

    srv._execute = stalled
    futs = [srv.submit(build_query(5, SF)) for _ in range(6)]
    closer = threading.Thread(
        target=srv.close, kwargs={"wait": True, "cancel_pending": True})
    closer.start()
    gate.set()
    closer.join(30)
    assert not closer.is_alive()
    for f in futs:
        assert f.done(), "future left pending after close()"
    with pytest.raises(RuntimeError):
        srv.submit(build_query(5, SF))


def test_close_default_drains_queued_work(tpch_small):
    """Default close(): queued requests run to completion before the
    workers exit."""
    cfg = ServeConfig(strategy="pred-trans", workers=2)
    srv = QueryServer(tpch_small, cfg)
    futs = [srv.submit(build_query(qn, SF)) for qn in (3, 5, 10)]
    srv.close(wait=True)
    for f in futs:
        assert f.done() and f.exception() is None


# -------------------------------------------------------------------------
# ft.runner re-export (satellite: taxonomy shared with training FT)
# -------------------------------------------------------------------------


def test_ft_runner_reexports_taxonomy():
    from repro.ft import runner
    assert runner.DeadlineExceeded is DeadlineExceeded
    assert runner.QueryContext is QueryContext
    assert issubclass(runner.BackendError, runner.QueryError)
