"""Property-based: predicate transfer preserves query semantics on random
micro-schemas (star + chain + cyclic joins, random local predicates,
inner/left/semi/anti), for every strategy."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.transfer import make_strategy
from repro.relational import Executor, Table, col
from repro.relational.plan import GroupBy, Join, Scan

STRATS = ["bloom-join", "yannakakis", "pred-trans", "pred-trans-opt",
          "pred-trans-adaptive"]


def _catalog(rng, na, nb, nc):
    return {
        "A": Table.from_arrays({
            "a_id": np.arange(na, dtype=np.int64),
            "a_v": rng.integers(0, 8, na).astype(np.int64)}, "A"),
        "B": Table.from_arrays({
            "b_id": np.arange(nb, dtype=np.int64),
            "b_a": rng.integers(0, max(na, 1), nb).astype(np.int64),
            "b_c": rng.integers(0, max(nc, 1), nb).astype(np.int64),
            "b_v": rng.integers(0, 8, nb).astype(np.int64)}, "B"),
        "C": Table.from_arrays({
            "c_id": np.arange(nc, dtype=np.int64),
            "c_v": rng.integers(0, 8, nc).astype(np.int64)}, "C"),
    }


def _agg(plan):
    return GroupBy(plan, [], [("cnt", "count", ""),
                              ("s", "sum", "b_id")])


def _run(catalog, plan_fn):
    out = {}
    for s in ["no-pred-trans"] + STRATS:
        res, _ = Executor(catalog, make_strategy(s)).execute(plan_fn())
        out[s] = (int(res.array("cnt")[0]), int(res.array("s")[0]))
    base = out.pop("no-pred-trans")
    for s, v in out.items():
        assert v == base, (s, v, base)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 40), st.integers(5, 200), st.integers(2, 40),
       st.integers(0, 7), st.integers(0, 7), st.integers(0, 2**31 - 1))
def test_chain_join_all_strategies(na, nb, nc, pa, pc, seed):
    rng = np.random.default_rng(seed)
    catalog = _catalog(rng, na, nb, nc)

    def plan():
        a = Scan("A", filter=col("a_v") >= pa)
        b = Scan("B")
        c = Scan("C", filter=col("c_v") >= pc)
        j = Join(b, a, ["b_a"], ["a_id"])
        j = Join(j, c, ["b_c"], ["c_id"])
        return _agg(j)

    _run(catalog, plan)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 30), st.integers(5, 150), st.integers(0, 7),
       st.sampled_from(["semi", "anti", "left"]),
       st.integers(0, 2**31 - 1))
def test_nonequi_join_kinds(na, nb, pa, how, seed):
    rng = np.random.default_rng(seed)
    catalog = _catalog(rng, na, nb, 4)

    def plan():
        a = Scan("A", filter=col("a_v") >= pa)
        b = Scan("B")
        j = Join(b, a, ["b_a"], ["a_id"], how=how)
        return _agg(j)

    _run(catalog, plan)


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 25), st.integers(10, 120), st.integers(3, 25),
       st.integers(0, 2**31 - 1))
def test_cyclic_join_graph(na, nb, nc, seed):
    """B joins A and C; A also joins C (via value columns) => cycle."""
    rng = np.random.default_rng(seed)
    catalog = _catalog(rng, na, nb, nc)

    def plan():
        a = Scan("A", filter=col("a_v") >= 3)
        b = Scan("B")
        c = Scan("C")
        j = Join(b, a, ["b_a"], ["a_id"])
        # second key pair closes a cycle a_v = c_v
        j = Join(j, c, ["b_c", "a_v"], ["c_id", "c_v"])
        return _agg(j)

    _run(catalog, plan)
