import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import: jax locks the device count on first
# backend initialization. 512 host devices back the production meshes.

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) cell and both production meshes
(single-pod 16x16, multi-pod 2x16x16):

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...)\
                      .lower(*input_specs(arch, shape))
        compiled = lowered.compile()
        compiled.memory_analysis()   # proves it fits 16 GB/chip
        compiled.cost_analysis()     # FLOPs/bytes for the roofline

plus collective-traffic extraction from the partitioned HLO. Results are
written incrementally to reports/dryrun/<cell>.json (resumable); failures
are real bugs and abort with the compiler error.

Usage:
    python -m repro.launch.dryrun [--arch A] [--shape S] [--mesh single|multi|both]
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import numpy as np

from repro.configs import ARCHS, SHAPES, get_config, shape_skip_reason
from repro.launch import hlo as H
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    TRAIN_SETTINGS, input_specs, microbatches_for, named,
)
from repro.models.model import Model
from repro.parallel import sharding as S
from repro.train import optim as O
from repro.train.step import TrainConfig, build_train_step

REPORT_DIR = os.path.join(os.path.dirname(__file__),
                          "..", "..", "..", "reports", "dryrun")


def opt_shardings(opt_state, param_specs, mesh):
    """Optimizer-state specs: moments follow their parameter; factored
    accumulators follow the parameter minus the reduced dim; scalars
    replicate."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    # AdamW: state.m / state.v mirror params; Adafactor: vr drops last
    # dim, vc drops second-to-last.
    import repro.train.optim as optim

    if isinstance(opt_state, optim.AdamWState):
        mspec = param_specs
        return optim.AdamWState(
            NamedSharding(mesh, P()),
            jax.tree.map(lambda s: NamedSharding(mesh, s), mspec),
            jax.tree.map(lambda s: NamedSharding(mesh, s), mspec))
    if isinstance(opt_state, optim.AdafactorState):
        def drop_last(s, leaf):
            t = tuple(s)
            t = t[: leaf.ndim] if len(t) > leaf.ndim else t
            return NamedSharding(mesh, P(*t))

        vr = jax.tree.map(
            lambda s, l: NamedSharding(
                mesh, S.fit_spec(P(*tuple(s)[:-1]) if len(tuple(s))
                                 else P(), l.shape, mesh)),
            param_specs, opt_state.vr)
        # vc shapes: param.shape[:-2] + param.shape[-1:]
        vc = jax.tree.map(
            lambda s, l: NamedSharding(
                mesh, S.fit_spec(
                    P(*(tuple(s)[:-2] + tuple(s)[-1:])) if len(tuple(s)) >= 2
                    else P(), l.shape, mesh)),
            param_specs, opt_state.vc)
        return optim.AdafactorState(NamedSharding(mesh, P()), vr, vc)
    raise TypeError(type(opt_state))


def build_cell(arch: str, shape: str, mesh) -> Dict[str, Any]:
    """Lower + compile one cell; return roofline-input metrics."""
    cfg = get_config(arch)
    model = Model(cfg)
    spec = SHAPES[shape]
    kind, args, arg_specs = input_specs(arch, shape, mesh, cfg)

    pshapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    # serving keeps weights replicated across data (no per-step weight
    # all-gather) — unless the per-TP-shard weight slice itself exceeds
    # the HBM budget (jamba-398B: 796 GB / 16 = 50 GB), where ZeRO-3
    # weight sharding stays on even for serving; training uses the
    # per-arch ZeRO-1/ZeRO-3 choice
    if kind == "train":
        use_fsdp = TRAIN_SETTINGS[arch].fsdp
    else:
        tp = mesh.shape.get("model", 1)
        use_fsdp = cfg.param_count() * 2.0 / tp > 12e9
    pspecs = S.param_specs(cfg, mesh, fsdp=use_fsdp)
    psh = named(mesh, pspecs)

    if kind == "train":
        ts = TRAIN_SETTINGS[arch]
        opt = O.make_optimizer(
            ts.optimizer, O.cosine_schedule(3e-4, 100, 10_000),
            state_dtype=ts.opt_state_dtype)
        m = microbatches_for(arch, cfg, mesh, spec)
        tc = TrainConfig(microbatches=m, remat=True,
                         loss_chunk=ts.loss_chunk,
                         accum_dtype=ts.accum_dtype)
        step_fn = build_train_step(model, opt, tc)
        oshapes = jax.eval_shape(opt.init, pshapes)
        osh = opt_shardings(oshapes, pspecs, mesh)
        in_sh = (psh, osh, named(mesh, arg_specs[0]))
        lowered = jax.jit(step_fn, in_shardings=in_sh,
                          donate_argnums=(0, 1)).lower(
            pshapes, oshapes, *args)
        extra_info = {"microbatches": m, "optimizer": ts.optimizer,
                      "fsdp": use_fsdp}
    elif kind == "prefill":
        cap = spec.seq_len

        def prefill_fn(params, batch):
            return model.prefill(params, batch, cap=cap)

        in_sh = (psh, named(mesh, arg_specs[0]))
        lowered = jax.jit(prefill_fn, in_shardings=in_sh).lower(
            pshapes, *args)
        extra_info = {}
    else:  # decode
        if cfg.n_enc_layers:
            def decode_fn(params, tok, caches, pos, enc):
                return model.decode_step(params, tok, caches, pos, enc)
        else:
            def decode_fn(params, tok, caches, pos):
                return model.decode_step(params, tok, caches, pos)
        in_sh = (psh,) + tuple(named(mesh, s) for s in arg_specs)
        lowered = jax.jit(decode_fn, in_shardings=in_sh).lower(
            pshapes, *args)
        extra_info = {}

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    text = compiled.as_text()
    coll = H.collective_stats(text)

    n_dev = int(np.prod(list(mesh.shape.values())))
    out = {
        "arch": arch, "shape": shape, "kind": kind,
        "mesh": dict(mesh.shape), "devices": n_dev,
        "compile_seconds": round(compile_s, 1),
        "flops_per_device": float(cost.get("flops", -1)),
        "bytes_accessed_per_device": float(cost.get("bytes accessed", -1)),
        "collectives": coll,
        "collective_bytes_per_device": H.collective_bytes(text),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes",
                                          -1)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", -1)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", -1)),
            "generated_code_bytes": int(getattr(
                mem, "generated_code_size_in_bytes", -1)),
        },
        "params": int(get_config(arch).param_count()),
        **extra_info,
    }
    return out


def cell_path(arch: str, shape: str, multi_pod: bool) -> str:
    os.makedirs(REPORT_DIR, exist_ok=True)
    tag = "multi" if multi_pod else "single"
    return os.path.join(REPORT_DIR, f"{arch}__{shape}__{tag}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes:
            reason = shape_skip_reason(cfg, shape)
            for multi in meshes:
                path = cell_path(arch, shape, multi)
                if os.path.exists(path) and not args.force:
                    print(f"SKIP (cached) {path}")
                    continue
                if reason is not None:
                    with open(path, "w") as f:
                        json.dump({"arch": arch, "shape": shape,
                                   "skip": reason}, f, indent=1)
                    print(f"SKIP {arch} x {shape}: {reason}")
                    continue
                mesh = make_production_mesh(multi_pod=multi)
                tag = "multi" if multi else "single"
                print(f"=== {arch} x {shape} x {tag} ===", flush=True)
                try:
                    with jax.set_mesh(mesh):
                        out = build_cell(arch, shape, mesh)
                    with open(path, "w") as f:
                        json.dump(out, f, indent=1)
                    mb = out["memory"]
                    print(f"  ok: compile={out['compile_seconds']}s "
                          f"flops/dev={out['flops_per_device']:.3e} "
                          f"coll_bytes/dev="
                          f"{out['collective_bytes_per_device']:.3e} "
                          f"args={mb['argument_bytes']/2**30:.2f}GiB "
                          f"temp={mb['temp_bytes']/2**30:.2f}GiB",
                          flush=True)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, tag, repr(e)))
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        return 1
    print("\nALL DRY-RUN CELLS PASSED")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
