"""Analytic per-device cost model for the roofline.

XLA's `cost_analysis()` counts each while-loop body once (scan over
layers / microbatches / loss chunks), so raw HLO numbers under-count by
the loop trip counts. The roofline therefore uses this explicit model —
every formula is written out below — and reports the raw HLO numbers
alongside for cross-checking (EXPERIMENTS.md §Roofline documents both).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (assignment-specified).

Conventions:
  * FLOPs: 2·m·n·k per matmul; causal attention scores+AV at half cost.
  * Training executes fwd (2·N·D) + bwd (4·N·D) + remat re-fwd (2·N·D):
    8·N·D matmul FLOPs against the 6·N·D "useful" MODEL_FLOPS.
  * Bytes: weight traffic per pass + optimizer state traffic + an
    activation-traffic term (reads+writes of layer activations).
  * Collectives: FSDP weight all-gather + gradient reduce-scatter over
    `data`, TP activation all-reduces over `model`, MoE all-to-all.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


from repro.configs import SHAPES, ShapeSpec
from repro.models.common import ModelConfig, moe_layer_indices

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link


@dataclasses.dataclass
class Cost:
    flops: float             # per device
    hbm_bytes: float         # per device
    coll_bytes: float        # per device
    model_flops: float       # global "useful" 6·N_act·D
    notes: str = ""

    def terms(self) -> Dict[str, float]:
        return {
            "compute_s": self.flops / PEAK_FLOPS,
            "memory_s": self.hbm_bytes / HBM_BW,
            "collective_s": self.coll_bytes / ICI_BW,
        }

    def bottleneck(self) -> str:
        t = self.terms()
        return max(t, key=t.get).replace("_s", "")


def _mesh_sizes(mesh_shape: Dict[str, int]):
    dp = mesh_shape.get("pod", 1) * mesh_shape.get("data", 1)
    tp = mesh_shape.get("model", 1)
    return dp, tp, dp * tp


def _attn_layers(cfg: ModelConfig) -> int:
    return sum(1 for i in range(cfg.n_layers)
               if cfg.layer_kind(i) == "attn")


def _mamba_layers(cfg: ModelConfig) -> int:
    return cfg.n_layers - _attn_layers(cfg)


def _attn_flops_fwd(cfg: ModelConfig, tokens_total: float,
                    kv_len: float, causal: bool) -> float:
    """Scores + AV for all attention layers (global FLOPs, fwd only)."""
    if cfg.attn is None:
        return 0.0
    a = cfg.attn
    eff = kv_len / 2 if causal else kv_len
    if a.sliding_window:
        eff = min(eff, a.sliding_window)
    per_tok = 2 * 2 * a.num_heads * a.head_dim * eff
    return per_tok * tokens_total * _attn_layers(cfg)


def _ssd_flops_fwd(cfg: ModelConfig, tokens_total: float) -> float:
    if cfg.mamba is None:
        return 0.0
    mb = cfg.mamba
    d_inner = mb.expand * cfg.d_model
    # intra-chunk "attention" (chunk-causal) + state path (d_state)
    per_tok = 2 * d_inner * (mb.chunk / 2 + 2 * mb.d_state)
    return per_tok * tokens_total * _mamba_layers(cfg)


def param_bytes(cfg: ModelConfig) -> float:
    return cfg.param_count() * 2.0          # bf16


def active_param_bytes(cfg: ModelConfig) -> float:
    return cfg.active_param_count() * 2.0


def train_cost(cfg: ModelConfig, spec: ShapeSpec, mesh_shape: Dict[str, int],
               microbatches: int, optimizer: str,
               opt_state_bytes_per_param: float,
               fsdp: bool = True,
               accum_bytes: float = 4.0) -> Cost:
    dp, tp, n_dev = _mesh_sizes(mesh_shape)
    D = spec.global_batch * spec.seq_len            # tokens
    n_act = cfg.active_param_count()
    n_tot = cfg.param_count()

    model_flops = 6.0 * n_act * D
    # executed: fwd + bwd + remat refwd = 8·N·D, plus attention/ssd terms
    # (x4: fwd + refwd + 2x bwd)
    mm = 8.0 * n_act * D
    attn = 4.0 * _attn_flops_fwd(cfg, D, spec.seq_len, causal=True)
    ssd = 4.0 * _ssd_flops_fwd(cfg, D)
    flops_dev = (mm + attn + ssd) / n_dev

    # HBM bytes / device
    p_shards = n_dev if fsdp else tp
    p_local = param_bytes(cfg) / p_shards
    opt_local = n_tot * opt_state_bytes_per_param / p_shards
    grad_local = n_tot * accum_bytes / p_shards
    weight_traffic = 3.0 * p_local * microbatches   # fwd+bwd+remat reads
    opt_traffic = 2.0 * (opt_local + grad_local) + 4.0 * p_local
    d_tok_local = D / dp                            # tokens per DP shard
    act_traffic = 12.0 * d_tok_local * cfg.d_model * 2.0 \
        * cfg.n_layers / tp
    logits_traffic = 4.0 * d_tok_local * cfg.vocab_size * 2.0 / tp
    hbm = weight_traffic + opt_traffic + act_traffic + logits_traffic

    # collectives / device
    if fsdp:
        # ZeRO-3: all-gather weights (per microbatch, fwd+remat+bwd) over
        # data, then reduce-scatter grads once
        w_coll = 3.0 * microbatches * (param_bytes(cfg) / tp) \
            * (dp - 1) / dp
        g_coll = (n_tot * accum_bytes / tp) * (dp - 1) / dp
    else:
        # ZeRO-1: weights resident; one gradient all-reduce (ring: 2x)
        w_coll = 0.0
        g_coll = 2.0 * (n_tot * accum_bytes / tp) * (dp - 1) / dp
    # TP: 2 all-reduces per layer fwd (+2x bwd) on activations
    tp_ar = 0.0 if tp == 1 else \
        4.0 * 2.0 * d_tok_local * cfg.d_model * 2.0 * cfg.n_layers \
        * (tp - 1) / tp
    # MoE all-to-all: dispatch+return of expert inputs/outputs (fwd+bwd)
    a2a = 0.0
    n_moe = len(moe_layer_indices(cfg))
    if n_moe and tp > 1:
        a2a = 4.0 * d_tok_local * cfg.moe.top_k * cfg.d_model * 2.0 \
            * n_moe * (tp - 1) / tp
    coll = w_coll + g_coll + tp_ar + a2a

    return Cost(flops_dev, hbm, coll, model_flops,
                notes=f"m={microbatches} opt={optimizer} "
                      f"{'zero3' if fsdp else 'zero1'}")


def prefill_cost(cfg: ModelConfig, spec: ShapeSpec,
                 mesh_shape: Dict[str, int]) -> Cost:
    dp, tp, n_dev = _mesh_sizes(mesh_shape)
    D = spec.global_batch * spec.seq_len
    n_act = cfg.active_param_count()
    model_flops = 2.0 * n_act * D
    mm = 2.0 * n_act * D
    attn = _attn_flops_fwd(cfg, D, spec.seq_len, causal=True)
    ssd = _ssd_flops_fwd(cfg, D)
    flops_dev = (mm + attn + ssd) / n_dev

    d_tok_local = D / dp
    # serving placement: weights sharded over model only (resident) when
    # they fit; 398B-class models stay ZeRO-3 sharded and re-gather
    fits = param_bytes(cfg) / tp <= 12e9
    p_local = param_bytes(cfg) / (tp if fits else n_dev)
    act = 8.0 * d_tok_local * cfg.d_model * 2.0 * cfg.n_layers / tp
    kv_write = _kv_cache_bytes(cfg, spec.global_batch, spec.seq_len) / n_dev
    hbm = p_local + act + kv_write

    tp_ar = 0.0 if tp == 1 else \
        2.0 * d_tok_local * cfg.d_model * 2.0 * cfg.n_layers * (tp - 1) / tp
    w_ag = 0.0 if fits else (param_bytes(cfg) / tp) * (dp - 1) / dp
    return Cost(flops_dev, hbm, tp_ar + w_ag, model_flops)


def _kv_cache_bytes(cfg: ModelConfig, batch: int, cap: int) -> float:
    if cfg.attn is None:
        a_bytes = 0.0
    elif cfg.attn.kv_lora_rank:
        a_bytes = batch * cap * (cfg.attn.kv_lora_rank
                                 + cfg.attn.rope_head_dim) * 2.0
    else:
        eff = min(cap, cfg.attn.sliding_window or cap)
        a_bytes = batch * eff * 2 * cfg.attn.num_kv_heads \
            * cfg.attn.head_dim * 2.0
    total = a_bytes * _attn_layers(cfg)
    if cfg.mamba is not None:
        mb = cfg.mamba
        d_inner = mb.expand * cfg.d_model
        nheads = d_inner // mb.head_dim
        total += (batch * nheads * mb.head_dim * mb.d_state * 4.0
                  + batch * (mb.d_conv - 1) * (d_inner + 2 * mb.d_state)
                  * 2.0) * _mamba_layers(cfg)
    return total


def decode_cost(cfg: ModelConfig, spec: ShapeSpec,
                mesh_shape: Dict[str, int]) -> Cost:
    dp, tp, n_dev = _mesh_sizes(mesh_shape)
    B = spec.global_batch                       # one token per sequence
    n_act = cfg.active_param_count()
    model_flops = 2.0 * n_act * B
    attn = _attn_flops_fwd(cfg, B, spec.seq_len, causal=False)
    ssd = _ssd_flops_fwd(cfg, B) if cfg.mamba else 0.0
    flops_dev = (2.0 * n_act * B + attn + ssd) / n_dev

    # decode is memory-bound: every step reads all (active) weights and
    # the whole KV cache; serving placement keeps weights resident
    # (sharded over model only) when they fit, else ZeRO-3 + re-gather
    fits = param_bytes(cfg) / tp <= 12e9
    p_read = active_param_bytes(cfg) / (tp if fits else n_dev)
    kv_read = _kv_cache_bytes(cfg, B, spec.seq_len) / n_dev
    hbm = p_read + kv_read

    tp_ar = 0.0 if tp == 1 else \
        2.0 * B * cfg.d_model * 2.0 * cfg.n_layers * (tp - 1) / tp
    w_ag = 0.0 if fits else (param_bytes(cfg) / tp) * (dp - 1) / dp
    return Cost(flops_dev, hbm, tp_ar + w_ag, model_flops)


def cell_cost(cfg: ModelConfig, shape: str, mesh_shape: Dict[str, int],
              microbatches: int = 1, optimizer: str = "adamw",
              opt_bytes_per_param: float = 8.0, fsdp: bool = True,
              accum_bytes: float = 4.0) -> Cost:
    spec = SHAPES[shape]
    if spec.kind == "train":
        return train_cost(cfg, spec, mesh_shape, microbatches, optimizer,
                          opt_bytes_per_param, fsdp=fsdp,
                          accum_bytes=accum_bytes)
    if spec.kind == "prefill":
        return prefill_cost(cfg, spec, mesh_shape)
    return decode_cost(cfg, spec, mesh_shape)
